"""Speculative-decoding proposers for the serving engine.

Speculative decoding splits each decode tick into *propose* (cheap:
guess ``k`` candidate tokens per active slot) and *verify* (one fused
chunk-extend dispatch of the target model scores all ``k + 1`` positions
through the page table and accepts the longest consistent run — see
``repro.serving.sampling.make_verify_step``).  The engine is agnostic to
where drafts come from; this module provides the two proposers behind
one interface:

- :class:`NgramProposer` — prompt-lookup decoding: index the n-gram
  continuations seen in the slot's own token history (prompt + generated
  output) and roll the modal continuation of the current suffix forward
  ``k`` tokens.  Zero device work; it shines on repetitive continuations
  (templated output, code, retrieved context echoed back).
- :class:`DraftProposer` — a small draft model (e.g. a reduced
  ``ds_paper_100m``) running greedy decode ahead of the target, with its
  OWN paged KV cache.  The draft cache mirrors the slot's accepted
  history; after each verify the engine's accepted count shows up as a
  shorter/longer history and the proposer resyncs by longest-common-
  prefix — rejected draft KV is rewound exactly like the target's
  (``KVCacheManager.rewind_slot``), never recomputed from scratch.

Contract (both proposers):

- ``propose(rows, histories, k)`` returns ``{row: [d1..dm]}``, ``m <= k``
  (an absent row or empty list degrades that row to plain decode inside
  the same verify dispatch — proposing nothing is always safe);
- proposals are *guesses*: nothing the proposer does may influence the
  target model's sampled tokens, only how many of them land per
  dispatch.  Byte parity with non-speculative decoding is enforced by
  the verify step, not trusted from here;
- ``release(row)`` drops per-row state when the engine retires the slot
  (best-effort: a stale row is also resynced lazily on its next
  propose, so preemptions that bypass the engine's tick are safe).
"""

from __future__ import annotations

from collections import Counter, defaultdict
from typing import Dict, List, Optional, Sequence

import numpy as np


class NgramProposer:
    """Prompt-lookup proposer: modal n-gram continuation over the slot's
    own history.

    Per row, an order-``n`` continuation table (for ``n`` in
    ``min_ngram..max_ngram``) counts every next-token seen after each
    n-gram of the history.  A draft rolls forward from the current
    suffix: at each of the ``k`` steps the longest n-gram with any
    recorded continuation votes, majority wins (falling back to shorter
    n-grams), and the predicted token extends the *lookup context only*
    — hypothetical tokens are never counted into the tables.  Taking the
    modal continuation instead of the single most recent occurrence
    (classic prompt-lookup) is markedly more robust on bursty-repetitive
    output, where the most recent occurrence is often the one break in
    an otherwise stable pattern.

    The tables update incrementally as a row's history grows (appends
    cost ``O(new tokens * max_ngram)`` per tick); any history that is
    not an extension of what was indexed — preemption, re-admission,
    slot reuse — triggers a rebuild, so rows may change identity without
    notice.  No device work; the draft "model" is the sequence's own
    self-similarity."""

    kind = "ngram"

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if max_ngram < min_ngram or min_ngram < 1:
            raise ValueError(
                f"need max_ngram >= min_ngram >= 1, got {max_ngram}/{min_ngram}"
            )
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        # per row: indexed history copy + {n: {ngram: Counter(next)}}
        self._hist: Dict[int, List[int]] = {}
        self._tables: Dict[int, Dict[int, Dict[tuple, Counter]]] = {}

    def _update(self, row: int, hist: List[int]) -> None:
        old = self._hist.get(row)
        if old is None or len(old) > len(hist) or old != hist[:len(old)]:
            self._tables[row] = {
                n: defaultdict(Counter)
                for n in range(self.min_ngram, self.max_ngram + 1)
            }
            start = 0
        else:
            start = len(old)
        tables = self._tables[row]
        for n in range(self.min_ngram, self.max_ngram + 1):
            for i in range(max(n, start), len(hist)):
                tables[n][tuple(hist[i - n:i])][hist[i]] += 1
        self._hist[row] = list(hist)

    def propose(
        self, rows: Sequence[int], histories: Dict[int, List[int]], k: int
    ) -> Dict[int, List[int]]:
        out = {}
        for i in rows:
            self._update(i, histories[i])
            out[i] = self._roll(i, histories[i], k)
        return out

    def _roll(self, row: int, hist: List[int], k: int) -> List[int]:
        tables = self._tables[row]
        ctx = list(hist)
        drafts: List[int] = []
        for _ in range(k):
            nxt = None
            for n in range(min(self.max_ngram, len(ctx)),
                           self.min_ngram - 1, -1):
                votes = tables[n].get(tuple(ctx[-n:]))
                if votes:
                    nxt = votes.most_common(1)[0][0]
                    break
            if nxt is None:
                break
            drafts.append(nxt)
            ctx.append(nxt)
        return drafts

    def release(self, row: int) -> None:
        self._hist.pop(row, None)
        self._tables.pop(row, None)


class DraftProposer:
    """Small-model proposer with its own paged KV cache.

    The draft model greedily decodes ``k`` tokens ahead of the target
    from the slot's accepted history.  Its cache is managed by a private
    :class:`~repro.serving.cache_manager.KVCacheManager` sized to the
    full per-slot reservation (the draft pool can never hit pressure, so
    it never evicts or preempts — recovery policy stays the target
    engine's business).

    Resync discipline: per row we record exactly which token prefix the
    draft cache holds KV for.  On each propose the row's current history
    is longest-common-prefix matched against that record; everything
    past the match is rewound (the verify step rejected it, or the slot
    was re-admitted with a different request) and the missing history
    suffix is caught up via the draft model's fused chunked prefill.
    After a fully-accepted verify the whole k-token draft KV is already
    resident, so steady state is zero catch-up prefill + ``k`` decode
    dispatches per tick.

    ``stats`` is the TARGET engine's counter block: draft device calls
    land in ``draft_dispatches`` (kept separate from ``dispatches`` so
    dispatches/token still describes the target model).  The private
    cache manager gets its own throwaway stats so draft pages never
    pollute the target's paged-pool accounting."""

    kind = "draft"
    _CATCHUP_CHUNK = 32

    def __init__(
        self,
        model,
        params,
        *,
        max_batch: int,
        max_len: int,
        spec_k: int,
        page_size: int = 16,
        stats=None,
    ):
        import jax
        import jax.numpy as jnp

        from repro.serving.cache_manager import KVCacheManager
        from repro.serving.types import EngineStats

        if not model.supports_paged_cache:
            raise ValueError(
                "draft proposer needs a pageable draft-model KV cache; arch "
                f"{model.cfg.name!r} (family {model.cfg.family!r}) has none"
            )
        if not model.supports_fused_prefill:
            raise ValueError(
                "draft proposer catches up history via fused prefill; arch "
                f"{model.cfg.name!r} does not support it"
            )
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.stats = stats
        # drafting runs up to spec_k positions past the target's frontier
        # (the last of which the target may reject), so the draft slot
        # reservation is max_len + spec_k positions, fully pre-reserved:
        # pressure-free by construction
        draft_len = max_len + spec_k
        pages_per_slot = -(-draft_len // page_size)
        self.cache = KVCacheManager(
            model,
            max_batch=max_batch,
            max_len=draft_len,
            stats=EngineStats(),
            cache_mode="paged",
            page_size=page_size,
            total_pages=max_batch * pages_per_slot,
            prefix_cache=False,
        )
        # tokens whose KV is resident per row, positions 0..len-1 (the
        # ground truth for lazy resync; never trust row identity)
        self._tokens: List[List[int]] = [[] for _ in range(max_batch)]
        vocab = model.cfg.vocab_size

        def prefill(params, cache, tokens, offsets, lengths):
            _, cache = model.prefill_chunk(params, cache, tokens, offsets, lengths)
            return cache

        def decode(params, cache, tokens, pos):
            logits, cache = model.decode_step(params, cache, tokens, pos)
            nxt = jnp.argmax(logits[:, 0, :vocab], axis=-1).astype(jnp.int32)
            return nxt, cache

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    # ---------------------------------------------------------------- state
    def _resync(self, rows, histories) -> None:
        """Rewind each row's draft cache to the longest common prefix of
        its resident tokens and the slot's current accepted history."""
        for i in rows:
            hist, res = histories[i], self._tokens[i]
            lcp = 0
            for a, b in zip(res, hist):
                if a != b:
                    break
                lcp += 1
            if lcp < len(res):
                self.cache.rewind_slot(i, lcp)
                del res[lcp:]

    def release(self, row: int) -> None:
        self.cache.rewind_slot(row, 0)
        self._tokens[row] = []

    # -------------------------------------------------------------- propose
    def propose(
        self, rows: Sequence[int], histories: Dict[int, List[int]], k: int
    ) -> Dict[int, List[int]]:
        rows = [i for i in rows if len(histories[i]) > 0]
        if not rows or k <= 0:
            return {}
        self._resync(rows, histories)
        # catch-up: make hist[:-1] resident (the final history token is
        # fed through the decode path below so its logits seed drafting)
        self._catch_up(rows, histories)
        B = self.max_batch
        drafts: Dict[int, List[int]] = {i: [] for i in rows}
        feed = {i: histories[i][-1] for i in rows}
        for _ in range(k):
            tokens = np.zeros((B, 1), np.int32)
            pos = np.zeros((B,), np.int32)
            for i in rows:
                # decode writes KV at the row's frontier; pre-reserved
                # pool: ensure_pages can neither yield nor preempt here
                self.cache.ensure_pages(i, len(self._tokens[i]) + 1,
                                        write_start=len(self._tokens[i]))
                tokens[i, 0] = feed[i]
                pos[i] = len(self._tokens[i])
            self.cache.push_table()
            nxt, self.cache.cache = self._decode(
                self.params, self.cache.cache, tokens, pos
            )
            nxt = np.asarray(nxt)
            if self.stats is not None:
                self.stats.draft_dispatches += 1
            for i in rows:
                self._tokens[i].append(feed[i])
                feed[i] = int(nxt[i])
                drafts[i].append(feed[i])
        return drafts

    def _catch_up(self, rows, histories) -> None:
        B, C = self.max_batch, self._CATCHUP_CHUNK
        while True:
            todo = [i for i in rows
                    if len(self._tokens[i]) < len(histories[i]) - 1]
            if not todo:
                return
            tokens = np.zeros((B, C), np.int32)
            offsets = np.zeros((B,), np.int32)
            lengths = np.zeros((B,), np.int32)
            plan: Dict[int, List[int]] = {}
            for i in todo:
                res = len(self._tokens[i])
                chunk = histories[i][res:res + C]
                if len(chunk) > len(histories[i]) - 1 - res:
                    chunk = chunk[:len(histories[i]) - 1 - res]
                self.cache.ensure_pages(i, res + len(chunk), write_start=res)
                tokens[i, :len(chunk)] = chunk
                offsets[i] = res
                lengths[i] = len(chunk)
                plan[i] = chunk
            self.cache.push_table()
            self.cache.cache = self._prefill(
                self.params, self.cache.cache, tokens, offsets, lengths
            )
            if self.stats is not None:
                self.stats.draft_dispatches += 1
            for i, chunk in plan.items():
                self._tokens[i].extend(chunk)

"""Parameter-sharding rules engine.

Maps every parameter leaf (by its pytree path and rank) to a
``PartitionSpec`` on the production mesh, with divisibility-checked
fallbacks: a dim that does not divide its assigned mesh axes is
replicated and the decision recorded, so e.g. whisper-tiny's 6 heads or
internvl2's 14 heads degrade gracefully to replicated attention while
their FFN/vocab still shard (DESIGN.md §3).

Policies:
- ``tp_axis``  : tensor-parallel mesh axis ("model").
- ``fsdp_axes``: axes over which parameters/optimizer state are
  additionally sharded ZeRO-3-style (() = pure TP + DP-replication;
  ("data",) = FSDP; ("pod","data") for the largest configs).
- ``ep``       : expert parallelism — expert dim over ``tp_axis`` when it
  divides; otherwise experts replicate and expert FFNs shard over tp
  (TP-inside-expert; mixtral's 8 experts on a 16-way axis).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingPolicy:
    tp_axis: str = "model"
    fsdp_axes: Tuple[str, ...] = ()
    ep: bool = True

    @property
    def fsdp(self) -> MeshAxes:
        if not self.fsdp_axes:
            return None
        return self.fsdp_axes if len(self.fsdp_axes) > 1 else self.fsdp_axes[0]


def axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


@dataclass
class RuleReport:
    """Decisions taken (for DESIGN/EXPERIMENTS and tests)."""

    fallbacks: List[str] = field(default_factory=list)
    ep_layers: bool = False

    def note(self, msg: str) -> None:
        if msg not in self.fallbacks:
            self.fallbacks.append(msg)


def _maybe(mesh: Mesh, axes: MeshAxes, dim: int, what: str, report: RuleReport) -> MeshAxes:
    size = axis_size(mesh, axes)
    if axes is None or size == 1:
        return None
    if dim % size == 0 and dim >= size:
        return axes
    report.note(f"{what}: dim {dim} !% {axes}({size}) -> replicated")
    return None


# --------------------------------------------------------------- leaf dispatch
def _leaf_spec(
    path: Tuple[str, ...],
    shape: Tuple[int, ...],
    mesh: Mesh,
    pol: ShardingPolicy,
    report: RuleReport,
) -> P:
    tp, fsdp = pol.tp_axis, pol.fsdp
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""
    stacked = any(k in ("layers", "dense_layers", "cross", "encoder") for k in path[:-1])
    key = f"{'/'.join(path)}"

    def spec(*base) -> P:
        """Pad-left with None for the stacked layer dim."""
        pad = len(shape) - len(base)
        return P(*([None] * pad + list(base)))

    nd = len(shape) - (1 if stacked else 0)

    # ---- embeddings / heads -----------------------------------------------
    if name == "embed":
        return P(_maybe(mesh, tp, shape[0], key, report), _maybe(mesh, fsdp, shape[1], key, report))
    if name == "lm_head":
        return P(_maybe(mesh, fsdp, shape[0], key, report), _maybe(mesh, tp, shape[1], key, report))
    if name == "pos":
        return spec(None, _maybe(mesh, fsdp, shape[-1], key, report))

    # ---- norms / scalars ----------------------------------------------------
    if parent in ("ln1", "ln2", "ln", "ln_f", "q_norm", "kv_norm", "norm_w") or name in (
        "A_log",
        "D",
        "dt_bias",
    ):
        return P(*([None] * len(shape)))
    if parent in ("conv_x",):
        if name == "w":
            return spec(None, _maybe(mesh, tp, shape[-1], key, report))
        return spec(_maybe(mesh, tp, shape[-1], key, report))
    if parent in ("conv_B", "conv_C"):
        return P(*([None] * len(shape)))

    # ---- attention ------------------------------------------------------------
    if name in ("wq", "wk", "wv"):
        return spec(
            _maybe(mesh, fsdp, shape[-2], key, report), _maybe(mesh, tp, shape[-1], key, report)
        )
    if name == "wo" and parent != "mlp" and parent != "moe" and parent != "shared":
        # attention output projection (row-parallel) — mlp/moe handled below
        return spec(
            _maybe(mesh, tp, shape[-2], key, report), _maybe(mesh, fsdp, shape[-1], key, report)
        )
    if name in ("bq", "bk", "bv"):
        return spec(_maybe(mesh, tp, shape[-1], key, report))

    # ---- MLA ---------------------------------------------------------------------
    if name in ("q_down", "kv_down"):
        return spec(_maybe(mesh, fsdp, shape[-2], key, report), None)
    if name in ("q_up", "k_up", "v_up"):
        return spec(None, _maybe(mesh, tp, shape[-1], key, report))

    # ---- MoE ----------------------------------------------------------------------
    if name == "router":
        return spec(_maybe(mesh, fsdp, shape[-2], key, report), None)
    if parent == "moe":  # expert weights live directly under "moe"
        if name in ("wi", "wg"):  # (E, D, F)
            e, dd, ff = shape[-3], shape[-2], shape[-1]
            if pol.ep and e % axis_size(mesh, tp) == 0:
                report.ep_layers = True
                return spec(tp, _maybe(mesh, fsdp, dd, key, report), None)
            report.note(f"{key}: EP off (E={e} !% tp) -> TP-inside-expert")
            return spec(None, _maybe(mesh, fsdp, dd, key, report), _maybe(mesh, tp, ff, key, report))
        if name == "wo":  # (E, F, D)
            e, ff, dd = shape[-3], shape[-2], shape[-1]
            if pol.ep and e % axis_size(mesh, tp) == 0:
                return spec(tp, None, _maybe(mesh, fsdp, dd, key, report))
            return spec(None, _maybe(mesh, tp, ff, key, report), _maybe(mesh, fsdp, dd, key, report))

    # ---- dense MLP (also moe "shared" expert, zamba "shared" mlp) ------------------
    if name in ("wi", "wg"):
        return spec(
            _maybe(mesh, fsdp, shape[-2], key, report), _maybe(mesh, tp, shape[-1], key, report)
        )
    if name == "wo":
        return spec(
            _maybe(mesh, tp, shape[-2], key, report), _maybe(mesh, fsdp, shape[-1], key, report)
        )

    # ---- SSM -----------------------------------------------------------------------
    if name in ("w_z", "w_x"):
        return spec(
            _maybe(mesh, fsdp, shape[-2], key, report), _maybe(mesh, tp, shape[-1], key, report)
        )
    if name in ("w_B", "w_C", "w_dt"):
        return spec(_maybe(mesh, fsdp, shape[-2], key, report), None)
    if name == "out_proj":
        return spec(
            _maybe(mesh, tp, shape[-2], key, report), _maybe(mesh, fsdp, shape[-1], key, report)
        )

    # ---- zamba2 shared-block extras ---------------------------------------------------
    if name in ("lora_a",):  # (n_inv, 2D, r)
        return P(None, _maybe(mesh, fsdp, shape[1], key, report), None)
    if name in ("lora_b",):  # (n_inv, r, HHD)
        return P(None, None, _maybe(mesh, tp, shape[2], key, report))
    if name == "down":  # (2D, D)
        return P(_maybe(mesh, fsdp, shape[0], key, report), _maybe(mesh, tp, shape[1], key, report))

    report.note(f"{key}: no rule -> replicated")
    return P(*([None] * len(shape)))


# ------------------------------------------------------------------ public API
def param_specs(
    params_shape: Any, mesh: Mesh, policy: ShardingPolicy
) -> Tuple[Any, RuleReport]:
    """params_shape: pytree of ShapeDtypeStruct/arrays -> pytree of PartitionSpec."""
    report = RuleReport()

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for kp, leaf in flat:
        path = tuple(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k)) for k in kp
        )
        specs.append(_leaf_spec(path, tuple(leaf.shape), mesh, policy, report))
    return jax.tree_util.tree_unflatten(treedef, specs), report


def param_shardings(params_shape: Any, mesh: Mesh, policy: ShardingPolicy):
    specs, report = param_specs(params_shape, mesh, policy)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs), report


def bytes_per_device(params_shape: Any, specs: Any, mesh: Mesh) -> int:
    """Parameter bytes on one device under the given specs."""
    total = 0
    leaves = jax.tree.leaves(params_shape)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    for leaf, spec in zip(leaves, spec_leaves):
        n = 1
        padded = tuple(spec) + (None,) * (len(leaf.shape) - len(spec))
        for d, ax in zip(leaf.shape, padded):
            shards = axis_size(mesh, ax)
            n *= -(-d // shards)
        total += n * leaf.dtype.itemsize
    return total


def choose_policy(
    params_shape: Any,
    mesh: Mesh,
    *,
    hbm_budget_bytes: int = 8 * 1024**3,
    multi_pod: bool = False,
    state_multiplier: float = 1.0,
) -> ShardingPolicy:
    """Pick FSDP axes so parameters + optimizer state leave room for
    activations.  ``state_multiplier`` scales the param bytes to the full
    training state (e.g. bf16 params + fp32 master + moments + grad
    accumulator ~ 5x); the optimizer state inherits the param specs, so
    the same escalation logic covers it.

    Pure TP first; escalate to FSDP over "data" (and "pod") when the
    per-device state bytes exceed ~half the HBM budget.
    """
    candidates = [
        ShardingPolicy(fsdp_axes=()),
        ShardingPolicy(fsdp_axes=("data",)),
    ]
    if multi_pod:
        candidates.append(ShardingPolicy(fsdp_axes=("pod", "data")))
    for pol in candidates:
        specs, _ = param_specs(params_shape, mesh, pol)
        state = bytes_per_device(params_shape, specs, mesh) * state_multiplier
        if state <= hbm_budget_bytes // 2:
            return pol
    return candidates[-1]

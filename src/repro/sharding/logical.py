"""Logical-axis activation sharding.

Model code annotates activations with *logical* axis names
(``shard(x, "batch", "seq", "heads", "head_dim")``); a rule set maps
logical names to mesh axes (or ``None`` = replicated).  Outside a rule
context the annotations are no-ops, so the same model code runs on a
single CPU device (smoke tests) and on the 512-chip production mesh
(dry-run) unchanged.

Rule sets are plain dicts; see :data:`TRAIN_RULES` / :data:`DECODE_RULES`
for the production defaults and `repro.sharding.rules` for parameter
sharding.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def _current() -> Optional[Tuple[Mesh, Dict[str, MeshAxes]]]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Dict[str, MeshAxes]):
    """Activate logical->mesh axis rules (thread-local)."""
    prev = _current()
    _state.ctx = (mesh, dict(rules))
    try:
        yield
    finally:
        _state.ctx = prev


def resolve(logical: Sequence[Optional[str]], rules: Dict[str, MeshAxes]) -> P:
    return P(*[rules.get(ax) if ax is not None else None for ax in logical])


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate ``x`` with a sharding constraint derived from logical axes.

    ``None`` entries mean "no constraint on this dim".  No-op when no rule
    context is active or when a named dim does not divide its mesh axes.
    """
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    if x.ndim != len(logical):
        raise ValueError(f"shard(): rank {x.ndim} vs logical axes {logical}")
    spec = []
    for dim, ax in zip(x.shape, logical):
        mesh_axes = rules.get(ax) if ax is not None else None
        if mesh_axes is None:
            spec.append(None)
            continue
        axes = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        # replicate rather than fail when the dim is too small / indivisible
        spec.append(mesh_axes if (size <= dim and dim % size == 0) else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def rule_divides(dim: int, logical: str) -> bool:
    """Does the active rule for ``logical`` shard a dim of this size?

    Lets model code choose between sharding strategies at trace time
    (e.g. expert-parallel vs TP-inside-expert in the MoE layer)."""
    ctx = _current()
    if ctx is None:
        return False
    mesh, rules = ctx
    mesh_axes = rules.get(logical)
    if mesh_axes is None:
        return False
    axes = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size > 1 and size <= dim and dim % size == 0


# ----------------------------------------------------------------- rule sets
# Production defaults for the (pod, data, model) / (data, model) meshes.
def train_rules(multi_pod: bool) -> Dict[str, MeshAxes]:
    dp: MeshAxes = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": dp,
        "cache_batch": dp,
        "act_batch": dp,  # batch sharding of FFN-local activations
        "act_embed": None,  # hidden-dim sharding of FFN inputs (decode)
        "act_heads": None,  # attention-out contraction sharding (decode)
        "seq": None,
        # Megatron-style sequence parallelism: set to 'model' to carry the
        # residual stream seq-sharded between blocks (TP boundary psums
        # become reduce-scatter + all-gather pairs, 2x fewer bytes)
        "residual_seq": None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        # dispatch buffers (E, C, D): capacity dim over the dp axes so the
        # buffer is data-sharded like the tokens it holds
        "expert_cap": dp,
        "ssm_inner": "model",
        "ssm_heads": "model",
        "ssm_state": None,
        "kv_seq": None,
    }


def decode_rules(multi_pod: bool, *, seq_sharded_kv: bool = False) -> Dict[str, MeshAxes]:
    r = train_rules(multi_pod)
    if seq_sharded_kv:
        # context parallelism: KV cache sequence dim over the dp axes
        # (long_500k: batch=1, so dp axes are free); heads stay on "model".
        r["kv_seq"] = ("pod", "data") if multi_pod else ("data",)
    return r

"""Sharding: logical activation axes + parameter-spec rules engine."""
from repro.sharding.logical import axis_rules, decode_rules, shard, train_rules  # noqa: F401
from repro.sharding.rules import (  # noqa: F401
    RuleReport,
    ShardingPolicy,
    bytes_per_device,
    choose_policy,
    param_shardings,
    param_specs,
)

"""DS run configuration — the paper's ``config.py`` as a typed dataclass.

Field names deliberately mirror the paper's Online Methods (Step 1:
Configuration) so anybody who has operated Distributed-CellProfiler /
-Fiji / -OmeZarrCreator can read a run config here unchanged.  Fields
that are AWS-billing specific keep their semantics under the simulated
spot market (``machine_price`` is the bid; the market can out-price you).
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class MachineType:
    """Catalogue entry for an instance type (the EC2 analogue)."""

    name: str
    vcpus: int
    memory_mb: int
    # simulated spot market properties
    on_demand_price: float = 1.0
    # TPU-adaptation: chips attached to this worker (a pod-slice size)
    chips: int = 0


# A small instance catalogue; examples/tests reference these by name.
MACHINE_CATALOGUE: Dict[str, MachineType] = {
    m.name: m
    for m in [
        MachineType("sim.small", vcpus=2, memory_mb=4096, on_demand_price=0.10),
        MachineType("sim.large", vcpus=8, memory_mb=16384, on_demand_price=0.40),
        MachineType("sim.xlarge", vcpus=16, memory_mb=65536, on_demand_price=1.60),
        MachineType("tpu.v5e-8", vcpus=8, memory_mb=65536, on_demand_price=4.0, chips=8),
        MachineType("tpu.v5e-256", vcpus=32, memory_mb=131072, on_demand_price=128.0, chips=256),
    ]
}


# Paper-fidelity knobs deliberately carried on DSConfig without a
# consumer in the simulation.  dslint R7 requires every field to be
# either consumed somewhere under src/repro/ or *refused* here with a
# written reason — an operator tuning an inert knob must be able to
# find out why it does nothing.  Wire a field up -> delete its entry.
INERT_PAPER_FIELDS: Dict[str, str] = {
    "ebs_vol_size_gb": (
        "paper Step-1 EC2 knob kept for config-file parity; the "
        "simulation has no block devices to size — only the paper's "
        "minimum-size validation (>= 22 GB) is enforced"
    ),
    "sqs_dead_letter_queue": (
        "paper names a separate SQS queue; the simulated DurableQueue "
        "keeps dead letters in an in-queue table instead (see "
        "core/queue.py), so the name is never dereferenced — kept so "
        "paper-shaped config files round-trip"
    ),
}


@dataclass
class DSConfig:
    """One Distributed-Something run (paper Step 1)."""

    # -- identity ---------------------------------------------------------
    app_name: str = "DistributedSomething"
    payload: str = "distributed-train"  # DOCKERHUB_TAG analogue: registered payload id

    # -- EC2/ECS ----------------------------------------------------------
    ecs_cluster: str = "default"
    cluster_machines: int = 4  # CLUSTER_MACHINES
    tasks_per_machine: int = 1  # TASKS_PER_MACHINE
    machine_type: List[str] = field(default_factory=lambda: ["sim.large"])
    machine_price: float = 0.5  # spot bid, $/hr
    ebs_vol_size_gb: int = 22

    # -- docker runtime ----------------------------------------------------
    docker_cores: int = 1  # copies of the script per container
    cpu_shares: int = 4096  # 1024 == 1 vCPU, ECS convention
    memory_mb: int = 8192
    seconds_to_start: float = 0.0

    # -- SQS ---------------------------------------------------------------
    sqs_queue_name: str = "DistributedSomethingQueue"
    sqs_message_visibility: float = 120.0
    sqs_dead_letter_queue: str = "DistributedSomethingDeadLetters"
    max_receive_count: int = 3

    # -- CloudWatch ---------------------------------------------------------
    log_group_name: str = "DistributedSomething"
    # idle alarm: terminate instances idle longer than this (paper: CPU<1%
    # for 15 consecutive minutes)
    idle_alarm_seconds: float = 15 * 60.0
    monitor_poll_seconds: float = 60.0
    # TTL (seconds, by object mtime) for cross-host KV prefix pages under
    # kvprefix/: the monitor sweeps expired pages at teardown.  None
    # disables the sweep (pages persist across runs); 0 clears the prefix
    kvprefix_ttl_seconds: Optional[float] = None
    # -- serving fleet defaults ---------------------------------------------
    # speculative decoding for distributed-serve fleets: "off", "ngram"
    # (prompt-lookup drafts from each request's own history) or "draft"
    # (a small draft model with its own paged cache).  These are the
    # fleet-level defaults operators copy into serve job templates (the
    # job dict's "speculative"/"spec_k" keys override per job); greedy
    # output is byte-identical either way, only tokens/dispatch changes
    speculative: str = "off"
    spec_k: int = 4
    # disaggregated serving role for distributed-serve fleets: "unified"
    # (the monolith — every worker prefills and decodes), "prefill"
    # (workers only ingest prompts, publish the full prompt's KV through
    # the prefix store and enqueue a sealed handoff record onto the
    # decode queue) or "decode" (workers lease handoff records, hydrate
    # the published pages on demand and run pure decode ticks).  Like
    # speculative/spec_k this is the fleet-level default operators copy
    # into serve job templates (the job dict's "worker_role" key is what
    # serve.py reads per job); split fleets need a "decode_queue" in the
    # job as well.  See docs/serving.md "Disaggregated prefill/decode".
    worker_role: str = "unified"
    # -- autoscaling ---------------------------------------------------------
    # "off" (static fleet, the paper's behaviour), "queue" (size to the
    # reported request-queue backlog) or "slo" (queue policy plus scale-up
    # on p99 TTFT breaches).  See core/autoscaler.py for the policy and
    # docs/serving.md for operator guidance.  min/max_workers bound the
    # fleet target; target p99 is in engine ticks (the unit serve leases
    # report); cooldowns are (virtual) seconds; max_step bounds how far
    # one decision may move the target.
    autoscale: str = "off"
    min_workers: int = 1
    max_workers: int = 8
    autoscale_queue_per_worker: int = 4
    autoscale_target_p99_ttft: float = 0.0
    autoscale_up_cooldown_seconds: float = 60.0
    autoscale_down_cooldown_seconds: float = 600.0
    autoscale_max_step: int = 2

    # -- idempotent restart (CHECK_IF_DONE) ----------------------------------
    check_if_done: bool = True  # CHECK_IF_DONE_BOOL
    expected_number_files: int = 1  # EXPECTED_NUMBER_FILES
    min_file_size_bytes: int = 1  # MIN_FILE_SIZE_BYTES
    necessary_string: str = ""  # NECESSARY_STRING

    # -- extra environment passed to the payload ("VARIABLE" in the paper) ---
    env: Dict[str, str] = field(default_factory=dict)

    # ------------------------------------------------------------------ io
    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "DSConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown DSConfig fields: {sorted(unknown)}")
        return cls(**d)

    @classmethod
    def from_json(cls, text: str) -> "DSConfig":
        return cls.from_dict(json.loads(text))

    def validate(self) -> None:
        if self.cluster_machines < 0:
            raise ValueError("cluster_machines must be >= 0")
        if self.tasks_per_machine < 1:
            raise ValueError("tasks_per_machine must be >= 1")
        for mt in self.machine_type:
            if mt not in MACHINE_CATALOGUE:
                raise ValueError(f"unknown machine type {mt!r}")
        if self.sqs_message_visibility <= 0:
            raise ValueError("sqs_message_visibility must be > 0")
        if self.ebs_vol_size_gb < 22:
            raise ValueError("ebs_vol_size_gb minimum allowed is 22")  # paper
        if self.speculative not in ("off", "ngram", "draft"):
            raise ValueError(
                f"speculative must be off|ngram|draft, got {self.speculative!r}"
            )
        if self.spec_k < 1:
            raise ValueError("spec_k must be >= 1")
        if self.worker_role not in ("unified", "prefill", "decode"):
            raise ValueError(
                "worker_role must be unified|prefill|decode, "
                f"got {self.worker_role!r}"
            )
        if self.autoscale not in ("off", "queue", "slo"):
            raise ValueError(
                f"autoscale must be off|queue|slo, got {self.autoscale!r}"
            )
        if self.min_workers < 1:
            raise ValueError("min_workers must be >= 1")
        if self.max_workers < self.min_workers:
            raise ValueError("max_workers must be >= min_workers")
        if self.autoscale_queue_per_worker < 1:
            raise ValueError("autoscale_queue_per_worker must be >= 1")
        if self.autoscale_max_step < 1:
            raise ValueError("autoscale_max_step must be >= 1")
        if (self.autoscale_up_cooldown_seconds < 0
                or self.autoscale_down_cooldown_seconds < 0):
            raise ValueError("autoscale cooldowns must be >= 0")
        if self.autoscale == "slo" and self.autoscale_target_p99_ttft <= 0:
            raise ValueError(
                "autoscale='slo' needs autoscale_target_p99_ttft > 0"
            )


@dataclass
class FleetFile:
    """Account-specific spot-fleet request (paper Step 3).

    The AWS-credential-shaped fields exist so the operator workflow
    matches the paper; the simulated market only uses the market fields.
    """

    iam_fleet_role: str = "arn:sim:iam::role/aws-ec2-spot-fleet-tagging-role"
    iam_instance_profile: str = "arn:sim:iam::instance-profile/ecsInstanceRole"
    key_name: str = "ds-key"
    subnet_id: str = "subnet-sim"
    security_groups: List[str] = field(default_factory=lambda: ["sg-sim"])
    image_id: str = "ami-ecs-optimized-sim"
    snapshot_id: str = "snap-sim"
    region: str = "us-sim-1"
    # market simulation knobs
    market_seed: int = 0
    preemption_rate_per_hour: float = 0.0  # per-instance
    capacity: int = 10_000
    startup_seconds: float = 5.0
    price_volatility: float = 0.0

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "FleetFile":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown FleetFile fields: {sorted(unknown)}")
        return cls(**d)

    @classmethod
    def from_json(cls, text: str) -> "FleetFile":
        return cls.from_dict(json.loads(text))


def load_config(path: str) -> DSConfig:
    with open(path) as f:
        cfg = DSConfig.from_json(f.read())
    cfg.validate()
    return cfg


def load_fleet_file(path: str) -> FleetFile:
    with open(path) as f:
        return FleetFile.from_json(f.read())

"""Object store — the framework's S3 analogue.

DS keeps *everything* durable in S3: input data, outputs, exported logs,
and the files that the ``CHECK_IF_DONE`` idempotent-restart machinery
counts.  We reproduce that contract over a local filesystem root with
S3-like semantics:

- flat key space (``bucket/key`` → ``root/key``), prefix listing,
- atomic writes (temp file + ``os.replace``) so a preempted worker can
  never leave a half-written "done" artifact,
- object metadata (size, mtime) for ``MIN_FILE_SIZE_BYTES`` checks.

Swapping in real S3/GCS is a matter of re-implementing this one class;
every other subsystem talks only to :class:`ObjectStore`.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from dataclasses import dataclass
from typing import Iterator, Optional


@dataclass(frozen=True)
class ObjectInfo:
    key: str
    size: int
    mtime: float


class ObjectStore:
    """Local-filesystem object store with S3-style keys."""

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)

    # -- paths -----------------------------------------------------------
    def _path(self, key: str) -> str:
        if key.startswith("/") or ".." in key.split("/"):
            raise ValueError(f"invalid object key: {key!r}")
        return os.path.join(self.root, key)

    # -- writes ----------------------------------------------------------
    def put_bytes(self, key: str, data: bytes) -> None:
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)  # atomic on POSIX
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def put_text(self, key: str, text: str) -> None:
        self.put_bytes(key, text.encode("utf-8"))

    def put_json(self, key: str, obj) -> None:
        self.put_text(key, json.dumps(obj, indent=2, sort_keys=True))

    def upload_file(self, local_path: str, key: str) -> None:
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), prefix=".tmp-")
        os.close(fd)
        shutil.copyfile(local_path, tmp)
        os.replace(tmp, path)

    # -- reads -----------------------------------------------------------
    def get_bytes(self, key: str) -> bytes:
        with open(self._path(key), "rb") as f:
            return f.read()

    def get_text(self, key: str) -> str:
        return self.get_bytes(key).decode("utf-8")

    def get_json(self, key: str):
        return json.loads(self.get_text(key))

    def download_file(self, key: str, local_path: str) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(local_path)), exist_ok=True)
        shutil.copyfile(self._path(key), local_path)

    def exists(self, key: str) -> bool:
        return os.path.isfile(self._path(key))

    def head(self, key: str) -> Optional[ObjectInfo]:
        path = self._path(key)
        if not os.path.isfile(path):
            return None
        st = os.stat(path)
        return ObjectInfo(key=key, size=st.st_size, mtime=st.st_mtime)

    def list(self, prefix: str = "") -> Iterator[ObjectInfo]:
        """Yield objects under ``prefix``, sorted by key (like S3 ListObjects)."""
        base = self.root
        results = []
        for dirpath, _dirnames, filenames in os.walk(base):
            for fn in filenames:
                if fn.startswith(".tmp-"):
                    continue
                full = os.path.join(dirpath, fn)
                key = os.path.relpath(full, base).replace(os.sep, "/")
                if key.startswith(prefix):
                    st = os.stat(full)
                    results.append(ObjectInfo(key=key, size=st.st_size, mtime=st.st_mtime))
        results.sort(key=lambda o: o.key)
        yield from results

    def delete(self, key: str) -> None:
        path = self._path(key)
        if os.path.isfile(path):
            os.unlink(path)

    def delete_prefix(self, prefix: str) -> int:
        n = 0
        for info in list(self.list(prefix)):
            self.delete(info.key)
            n += 1
        return n

"""Durable work queue — the framework's SQS analogue.

This is the heart of the paper's fault-tolerance story and is reproduced
with full SQS semantics:

- **at-least-once delivery**: a received message is *hidden*, not removed;
  if the worker never calls :meth:`delete` (crash, preemption, stall) the
  message becomes visible again after its *visibility timeout* and another
  worker picks it up (paper: ``SQS_MESSAGE_VISIBILITY``);
- **visibility extension**: long-running jobs keep extending their lease
  (``change_visibility``), the DS worker loop does this from a heartbeat;
- **dead-letter queue**: after ``max_receive_count`` receives a message is
  moved to the DLQ instead of being retried forever, so one poison job
  "(such as one where a single file has been corrupted)" cannot keep the
  cluster alive indefinitely (paper: ``SQS_DEAD_LETTER_QUEUE``);
- **approximate counts**: visible vs in-flight, which the monitor polls
  once per "minute" to drive autoscaling and teardown.

Durability is SQLite (WAL journal): the queue file survives process
crashes, and all state transitions are single transactions.  A
``VirtualClock`` can be injected so tests control time.
"""

from __future__ import annotations

import json
import os
import sqlite3
import threading
import uuid
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

from .clock import Clock, WallClock

# Fault-injection seam for chaos drills.  Hooks are registered per queue
# *path*, not per instance: every lease opens its own handle on the same
# sqlite file, so an instance-level wrapper would miss the consumers that
# matter.  A registered hook is called as ``hook(op, path)`` before the
# consumer-side operations ("receive" / "delete"); raising from the hook
# makes the call fail exactly as a transient network fault would, without
# touching queue state.  Producer-side sends are never faulted — the
# drills target the worker's retry discipline, not test setup.
_FAULT_HOOKS: Dict[str, Callable[[str, str], None]] = {}


def install_fault_hook(path: str, hook: Callable[[str, str], None]) -> None:
    """Register (or replace) the fault hook for a queue path."""
    _FAULT_HOOKS[os.path.abspath(path)] = hook


def remove_fault_hook(path: str) -> None:
    _FAULT_HOOKS.pop(os.path.abspath(path), None)

_SCHEMA = """
CREATE TABLE IF NOT EXISTS messages (
    id            TEXT PRIMARY KEY,
    body          TEXT NOT NULL,
    enqueued_at   REAL NOT NULL,
    visible_at    REAL NOT NULL,
    receive_count INTEGER NOT NULL DEFAULT 0,
    receipt       TEXT
);
-- composite index: the claim query filters on visible_at and orders by
-- enqueued_at — one index serves both, so batch claims stay a single
-- range scan instead of a scan + sort.  It prefix-subsumes the old
-- single-column idx_visible, which is dropped to keep writes single-index.
DROP INDEX IF EXISTS idx_visible;
CREATE INDEX IF NOT EXISTS idx_ready ON messages (visible_at, enqueued_at);
CREATE TABLE IF NOT EXISTS dead_letters (
    id            TEXT PRIMARY KEY,
    body          TEXT NOT NULL,
    enqueued_at   REAL NOT NULL,
    died_at       REAL NOT NULL,
    receive_count INTEGER NOT NULL
);
"""


@dataclass
class Message:
    id: str
    body: Any
    receipt: str
    receive_count: int


class DurableQueue:
    """SQLite-backed queue with SQS visibility-timeout semantics."""

    def __init__(
        self,
        path: str,
        *,
        default_visibility: float = 60.0,
        max_receive_count: int = 3,
        clock: Optional[Clock] = None,
    ):
        self.path = path
        self._fault_key = os.path.abspath(path)
        self.default_visibility = float(default_visibility)
        self.max_receive_count = int(max_receive_count)
        self.clock = clock or WallClock()
        self._lock = threading.Lock()
        if os.path.dirname(path):
            os.makedirs(os.path.dirname(path), exist_ok=True)
        self._conn = sqlite3.connect(path, check_same_thread=False)
        self._conn.execute("PRAGMA journal_mode=WAL")
        self._conn.execute("PRAGMA synchronous=NORMAL")
        with self._lock, self._conn:
            self._conn.executescript(_SCHEMA)

    def _maybe_fault(self, op: str) -> None:
        hook = _FAULT_HOOKS.get(self._fault_key)
        if hook is not None:
            hook(op, self.path)

    # -- producer ----------------------------------------------------------
    def send(self, body: Any) -> str:
        return self.send_batch([body])[0]

    def send_batch(self, bodies: List[Any]) -> List[str]:
        now = self.clock.now()
        rows = [(uuid.uuid4().hex, json.dumps(body), now, now) for body in bodies]
        with self._lock, self._conn:
            self._conn.executemany(
                "INSERT INTO messages (id, body, enqueued_at, visible_at) VALUES (?,?,?,?)",
                rows,
            )
        return [r[0] for r in rows]

    # -- consumer ----------------------------------------------------------
    def receive(self, visibility_timeout: Optional[float] = None) -> Optional[Message]:
        """Atomically claim the oldest visible message, or ``None``.

        Messages that have exceeded ``max_receive_count`` are moved to the
        dead-letter table at claim time (SQS redrive policy).
        """
        msgs = self.receive_batch(1, visibility_timeout)
        return msgs[0] if msgs else None

    def receive_batch(
        self, max_messages: int = 10, visibility_timeout: Optional[float] = None
    ) -> List[Message]:
        """Atomically claim up to ``max_messages`` oldest visible messages
        in ONE transaction (SQS ``ReceiveMessage`` with ``MaxNumber...``).

        High-fanout consumers previously paid one lock acquisition + SQL
        round-trip per job; this claims a whole batch under a single lock
        with a single indexed range scan, DLQ-ing poison messages as they
        are encountered.  Returns fewer than ``max_messages`` (possibly
        none) if the queue runs dry.
        """
        self._maybe_fault("receive")
        vt = self.default_visibility if visibility_timeout is None else float(visibility_timeout)
        now = self.clock.now()
        claimed: List[Message] = []
        seen: set = set()  # ids handled this call: with vt <= 0 a claimed
        #                    message stays visible and would be re-selected
        #                    forever (duplicate delivery + spurious DLQ)
        with self._lock, self._conn:
            while len(claimed) < max_messages:
                # over-fetch by len(seen): still-visible already-claimed rows
                # (vt <= 0) sit at the front of the ordering and must not
                # mask unseen candidates behind the LIMIT
                want = max_messages - len(claimed) + len(seen)
                # tie-break equal enqueued_at by rowid (insertion order),
                # not id: ids are uuid4, so an id tie-break shuffles the
                # claim order of same-instant messages from run to run —
                # rowid keeps claim order FIFO and replay-deterministic
                # (release() is an UPDATE, so a message keeps its rowid)
                rows = self._conn.execute(
                    "SELECT id, body, enqueued_at, receive_count FROM messages "
                    "WHERE visible_at <= ? ORDER BY enqueued_at, rowid LIMIT ?",
                    (now, want),
                ).fetchall()
                rows = [r for r in rows if r[0] not in seen][: max_messages - len(claimed)]
                if not rows:
                    break
                for mid, body, enq, rc in rows:
                    seen.add(mid)
                    if rc >= self.max_receive_count:
                        # poison message -> DLQ
                        self._conn.execute("DELETE FROM messages WHERE id = ?", (mid,))
                        self._conn.execute(
                            "INSERT OR REPLACE INTO dead_letters VALUES (?,?,?,?,?)",
                            (mid, body, enq, now, rc),
                        )
                        continue
                    receipt = uuid.uuid4().hex
                    self._conn.execute(
                        "UPDATE messages SET visible_at = ?, receive_count = ?, receipt = ? "
                        "WHERE id = ?",
                        (now + vt, rc + 1, receipt, mid),
                    )
                    claimed.append(
                        Message(
                            id=mid,
                            body=json.loads(body),
                            receipt=receipt,
                            receive_count=rc + 1,
                        )
                    )
        return claimed

    def delete(self, message: Message) -> bool:
        """Acknowledge successful processing.  Receipt-checked like SQS —
        a stale receipt (message already re-delivered elsewhere) is a no-op."""
        return self.delete_batch([message]) == 1

    def delete_batch(self, messages: List[Message]) -> int:
        """Acknowledge a batch in one transaction (SQS ``DeleteMessageBatch``).

        Returns the number actually deleted; stale receipts are no-ops,
        mirroring :meth:`delete`."""
        self._maybe_fault("delete")
        with self._lock, self._conn:
            deleted = 0
            for m in messages:
                cur = self._conn.execute(
                    "DELETE FROM messages WHERE id = ? AND receipt = ?",
                    (m.id, m.receipt),
                )
                deleted += cur.rowcount
            return deleted

    def change_visibility(self, message: Message, visibility_timeout: float) -> bool:
        """Extend (or shrink) the lease on an in-flight message."""
        now = self.clock.now()
        with self._lock, self._conn:
            cur = self._conn.execute(
                "UPDATE messages SET visible_at = ? WHERE id = ? AND receipt = ?",
                (now + float(visibility_timeout), message.id, message.receipt),
            )
            return cur.rowcount > 0

    def release(self, message: Message, delay: float = 0.0) -> bool:
        """Return a message to the queue WITHOUT consuming retry budget.

        Used for not-ready-yet jobs (e.g. a training span whose
        prerequisite checkpoint has not landed): the receive is undone
        (receive_count decremented) and the message reappears after
        ``delay`` — waiting on a dependency must not march a job toward
        the dead-letter queue."""
        now = self.clock.now()
        with self._lock, self._conn:
            # re-enqueue at the BACK (enqueued_at = now): a waiting job must
            # not starve runnable jobs behind it in FIFO order
            cur = self._conn.execute(
                "UPDATE messages SET visible_at = ?, enqueued_at = ?, "
                "receive_count = receive_count - 1, receipt = NULL "
                "WHERE id = ? AND receipt = ?",
                (now + float(delay), now, message.id, message.receipt),
            )
            return cur.rowcount > 0

    # -- introspection -------------------------------------------------------
    def counts(self) -> dict:
        """Approximate numbers the monitor polls: visible / in-flight / dead."""
        now = self.clock.now()
        with self._lock:
            visible = self._conn.execute(
                "SELECT COUNT(*) FROM messages WHERE visible_at <= ?", (now,)
            ).fetchone()[0]
            inflight = self._conn.execute(
                "SELECT COUNT(*) FROM messages WHERE visible_at > ?", (now,)
            ).fetchone()[0]
            dead = self._conn.execute("SELECT COUNT(*) FROM dead_letters").fetchone()[0]
        return {"visible": visible, "in_flight": inflight, "dead": dead}

    def dead_letters(self) -> List[Message]:
        with self._lock:
            rows = self._conn.execute(
                "SELECT id, body, receive_count FROM dead_letters ORDER BY died_at"
            ).fetchall()
        return [Message(id=r[0], body=json.loads(r[1]), receipt="", receive_count=r[2]) for r in rows]

    def redrive_dead_letters(self) -> int:
        """Move DLQ messages back to the main queue (operator action)."""
        now = self.clock.now()
        with self._lock, self._conn:
            rows = self._conn.execute("SELECT id, body FROM dead_letters").fetchall()
            for mid, body in rows:
                self._conn.execute(
                    "INSERT OR REPLACE INTO messages (id, body, enqueued_at, visible_at, receive_count)"
                    " VALUES (?,?,?,?,0)",
                    (mid, body, now, now),
                )
            self._conn.execute("DELETE FROM dead_letters")
        return len(rows)

    def purge(self) -> None:
        with self._lock, self._conn:
            self._conn.execute("DELETE FROM messages")
            self._conn.execute("DELETE FROM dead_letters")

    def close(self) -> None:
        with self._lock:
            self._conn.close()

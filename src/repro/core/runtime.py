"""DSRuntime — wires queue/fleet/cluster/monitor/workers into the paper's
four-command lifecycle, with two execution backends:

- :class:`SimRunner` — deterministic, tick-driven, virtual-clock execution
  used by tests and benchmarks to exercise control-plane semantics
  (preemption, stragglers, autoscaling, DLQ) reproducibly;
- :class:`ThreadRunner` — real threads + wall clock, used by the examples
  to actually parallelize JAX work across local workers.

The lifecycle mirrors the paper exactly:

    rt = DSRuntime(cfg, store_root=...)
    rt.setup()                      # python run.py setup
    rt.submit_job(job_file)         # python run.py submitJob files/job.json
    rt.start_cluster(fleet_file)    # python run.py startCluster files/fleet.json
    rt.run_monitor()                # python run.py monitor <app>SpotFleetRequestId.json
"""

from __future__ import annotations

import os
import threading
from dataclasses import dataclass
from typing import Dict, List, Optional

from .autoscaler import Autoscaler, ProgressBoard
from .clock import Clock, VirtualClock, WallClock
from .cluster import ECSCluster, Service, TaskDefinition
from .config import DSConfig, FleetFile
from .fleet import SpotFleet
from .jobs import JobFile
from .logs import LogGroup, MetricRegistry
from .monitor import Monitor
from .queue import DurableQueue
from .storage import ObjectStore
from .worker import Worker


@dataclass
class RunSummary:
    jobs_done: int
    jobs_skipped: int
    jobs_failed: int
    dead_letters: int
    preemptions: int
    idle_terminations: int
    ticks: int
    wall_time: float


class DSRuntime:
    def __init__(
        self,
        cfg: DSConfig,
        *,
        store_root: str,
        clock: Optional[Clock] = None,
        workdir: Optional[str] = None,
    ):
        cfg.validate()
        self.cfg = cfg
        self.clock = clock or WallClock()
        self.store = ObjectStore(store_root)
        self.workdir = workdir or os.path.join(store_root, "_runtime")
        os.makedirs(self.workdir, exist_ok=True)
        self.logs = LogGroup(cfg.log_group_name, clock=self.clock)
        self.metrics = MetricRegistry(clock=self.clock)
        self.queue: Optional[DurableQueue] = None
        self.cluster = ECSCluster(cfg.ecs_cluster)
        self.fleet: Optional[SpotFleet] = None
        self.task_definition: Optional[TaskDefinition] = None
        self.monitor: Optional[Monitor] = None
        # latest heartbeat progress payload per worker (autoscaler input)
        self.progress_board = ProgressBoard()
        self.autoscaler: Optional[Autoscaler] = None
        self._submitted = 0

    # ------------------------------------------------------------ step 1: setup
    def setup(self) -> None:
        """Create task definition, queue (+DLQ), and the ECS service."""
        self.task_definition = TaskDefinition.from_config(self.cfg)
        self.queue = DurableQueue(
            os.path.join(self.workdir, f"{self.cfg.sqs_queue_name}.sqlite"),
            default_visibility=self.cfg.sqs_message_visibility,
            max_receive_count=self.cfg.max_receive_count,
            clock=self.clock,
        )
        self.cluster.register_service(
            Service(
                name=f"{self.cfg.app_name}Service",
                task_definition=self.task_definition,
                desired_count=self.cfg.cluster_machines * self.cfg.tasks_per_machine,
            )
        )
        self.logs.put("runtime", "setup complete: task definition + queue + service")

    # -------------------------------------------------------- step 2: submitJob
    def submit_job(self, job_file: JobFile) -> int:
        if self.queue is None:
            raise RuntimeError("call setup() before submit_job()")
        bodies = job_file.expand()
        self.queue.send_batch(bodies)
        self._submitted += len(bodies)
        self.logs.put("runtime", f"submitted {len(bodies)} jobs")
        return len(bodies)

    # ------------------------------------------------------ step 3: startCluster
    def start_cluster(self, fleet_file: FleetFile) -> str:
        self.fleet = SpotFleet(fleet_file, clock=self.clock, app_name=self.cfg.app_name)
        request_id = self.fleet.request(
            target_capacity=self.cfg.cluster_machines,
            bid=self.cfg.machine_price,
            machine_types=self.cfg.machine_type,
        )
        # DS drops <APP_NAME>SpotFleetRequestId.json for the monitor
        self.store.put_json(
            f"{self.cfg.app_name}SpotFleetRequestId.json",
            {"request_id": request_id, "app_name": self.cfg.app_name},
        )
        self.logs.put("runtime", f"spot fleet requested: {request_id}")
        return request_id

    # ---------------------------------------------------------- step 4: monitor
    def make_monitor(self, cheapest: bool = False, chaos=None) -> Monitor:
        if self.queue is None or self.fleet is None:
            raise RuntimeError("setup() and start_cluster() must run first")
        if self.cfg.autoscale != "off":
            self.autoscaler = Autoscaler(
                self.cfg,
                self.queue,
                self.fleet,
                self.cluster,
                clock=self.clock,
                logs=self.logs,
                board=self.progress_board,
            )
        self.monitor = Monitor(
            self.cfg,
            self.queue,
            self.fleet,
            self.cluster,
            self.logs,
            self.metrics,
            self.store,
            clock=self.clock,
            cheapest=cheapest,
            autoscaler=self.autoscaler,
            chaos=chaos,
        )
        return self.monitor


class SimRunner:
    """Deterministic tick-driven execution of a DSRuntime.

    Each tick: advance the market, place tasks, let every placed task
    process at most one message (heartbeating through the virtual clock),
    then run a monitor poll.  Preemption/straggler behaviour is exact and
    reproducible given the fleet-file seed.
    """

    def __init__(
        self,
        rt: DSRuntime,
        *,
        tick_seconds: float = 60.0,
        cheapest: bool = False,
        prefetch: int = 1,
        chaos=None,
        on_tick=None,
    ):
        if not isinstance(rt.clock, VirtualClock):
            raise TypeError("SimRunner requires a VirtualClock runtime")
        self.rt = rt
        self.tick_seconds = tick_seconds
        # chaos: a ChaosMonkey whose time-triggered faults fire from the
        # monitor poll and whose beat-triggered faults fire from the
        # heartbeat path (mid-payload).  on_tick(tick_number): a hook
        # called at the top of every tick — benchmarks inject request
        # arrivals through it without subclassing the runner.
        self.chaos = chaos
        self.on_tick = on_tick
        self.monitor = rt.make_monitor(cheapest=cheapest, chaos=chaos)
        self._workers: Dict[str, Worker] = {}
        self.preemptions = 0
        # prefetch > 1: workers claim job batches in ONE queue transaction
        # (DurableQueue.receive_batch) and drain the buffer across ticks.
        # Keep prefetch * tick_seconds below the visibility timeout or
        # buffered jobs get re-delivered (at-least-once, so still correct,
        # just wasteful).
        self.prefetch = max(1, int(prefetch))

    def _worker_for_task(self, task_id: str, instance_id: str) -> Worker:
        if task_id not in self._workers:
            fleet = self.rt.fleet
            inst = fleet.instances[instance_id]

            def is_terminated(inst=inst):
                return inst.state.value == "terminated"

            def on_heartbeat(inst=inst):
                # a delay_heartbeat fault suppresses the liveness record
                # (the idle alarm then sees a silent host); beat-triggered
                # faults fire here so a kill can land mid-slice
                ch = self.chaos
                if ch is None or ch.allow_heartbeat(inst):
                    inst.last_heartbeat = self.rt.clock.now()
                if ch is not None:
                    ch.on_beat(inst)

            def is_revoked(inst=inst):
                return inst.revoke_at is not None

            worker_id = f"{instance_id}/{task_id}"

            def on_progress(payload, wid=worker_id):
                self.rt.progress_board.put(wid, payload, self.rt.clock.now())

            self._workers[task_id] = Worker(
                worker_id=worker_id,
                queue=self.rt.queue,
                store=self.rt.store,
                logs=self.rt.logs,
                metrics=self.rt.metrics,
                task=self.rt.task_definition,
                clock=self.rt.clock,
                visibility=self.rt.cfg.sqs_message_visibility,
                is_terminated=is_terminated,
                on_heartbeat=on_heartbeat,
                is_revoked=is_revoked,
                on_progress=on_progress,
                prefetch=self.prefetch,
            )
        return self._workers[task_id]

    def run(self, max_ticks: int = 10_000) -> RunSummary:
        rt = self.rt
        start = rt.clock.now()
        ticks = 0
        idle_terms = 0
        while ticks < max_ticks:
            ticks += 1
            if self.on_tick is not None:
                self.on_tick(ticks)
            terminated = rt.fleet.tick()
            self.preemptions += sum(
                1 for i in terminated
                if i.terminate_reason in (
                    "spot-preemption", "price-above-bid",
                    "spot-revocation", "chaos-kill",
                )
            )
            rt.cluster.reap_dead_tasks(rt.fleet)
            placed = rt.cluster.place(f"{rt.cfg.app_name}Service", rt.fleet, rt.clock.now())
            del placed
            # every live task processes at most one message this tick
            for tid, task in list(rt.cluster.tasks.items()):
                inst = rt.fleet.instances.get(task.instance_id)
                if inst is None or inst.state.value != "running":
                    continue
                worker = self._worker_for_task(tid, task.instance_id)
                for _ in range(rt.task_definition.docker_cores):
                    outcome = worker.process_one()
                    # "yielded" ends the tick for this worker too: a lease
                    # slice is a full tick's budget — re-claiming it in the
                    # same tick would let one worker starve the others
                    if outcome in (None, "preempted", "yielded"):
                        break
            report = self.monitor.tick()
            idle_terms += len(report.idle_terminations)
            if report.finished:
                break
            rt.clock.sleep(self.tick_seconds)
        done = sum(w.jobs_done for w in self._workers.values())
        skipped = sum(w.jobs_skipped for w in self._workers.values())
        failed = sum(w.jobs_failed for w in self._workers.values())
        return RunSummary(
            jobs_done=done,
            jobs_skipped=skipped,
            jobs_failed=failed,
            dead_letters=len(self.rt.queue.dead_letters()) if not self.monitor.finished else 0,
            preemptions=self.preemptions,
            idle_terminations=idle_terms,
            ticks=ticks,
            wall_time=rt.clock.now() - start,
        )


class ThreadRunner:
    """Real-thread execution: one thread per (machine × tasks_per_machine).

    Used by the examples to run actual JAX training jobs in parallel on
    the local host.  Fleet semantics (startup delay, preemption) still
    apply through the shared clock.
    """

    def __init__(self, rt: DSRuntime, *, cheapest: bool = False, prefetch: int = 1):
        self.rt = rt
        self.monitor = rt.make_monitor(cheapest=cheapest)
        self.threads: List[threading.Thread] = []
        self.workers: List[Worker] = []
        self.prefetch = max(1, int(prefetch))

    def _spawn(self, tid: str, poll_interval: float) -> None:
        rt = self.rt
        task = rt.cluster.tasks[tid]
        inst = rt.fleet.instances[task.instance_id]

        def is_terminated(inst=inst):
            return inst.state.value == "terminated"

        def on_heartbeat(inst=inst):
            inst.last_heartbeat = rt.clock.now()

        def is_revoked(inst=inst):
            return inst.revoke_at is not None

        worker_id = f"{inst.id}/{tid}"

        def on_progress(payload, wid=worker_id):
            rt.progress_board.put(wid, payload, rt.clock.now())

        worker = Worker(
            worker_id=worker_id,
            queue=rt.queue,
            store=rt.store,
            logs=rt.logs,
            metrics=rt.metrics,
            task=rt.task_definition,
            clock=rt.clock,
            visibility=rt.cfg.sqs_message_visibility,
            is_terminated=is_terminated,
            on_heartbeat=on_heartbeat,
            is_revoked=is_revoked,
            on_progress=on_progress,
            prefetch=self.prefetch,
        )
        self.workers.append(worker)
        t = threading.Thread(target=worker.run, args=(poll_interval,), daemon=True)
        self._threads_by_task[tid] = t
        self.threads.append(t)
        t.start()

    def run(self, poll_interval: float = 0.02, monitor_interval: float = 0.05) -> RunSummary:
        rt = self.rt
        start = rt.clock.now()
        self._threads_by_task: Dict[str, threading.Thread] = {}
        rt.fleet.tick()
        # wait for initial capacity
        while not rt.fleet.running():
            rt.clock.sleep(0.05)
            rt.fleet.tick()

        # monitor loop on this thread; placement + worker (re)spawn are part
        # of it so replacement instances get workers and workers that shut
        # down while a retried job was invisible are restarted
        ticks = 0
        idle_terms = 0
        while True:
            ticks += 1
            rt.fleet.tick()
            rt.cluster.reap_dead_tasks(rt.fleet)
            rt.cluster.place(f"{rt.cfg.app_name}Service", rt.fleet, rt.clock.now())
            counts = rt.queue.counts()
            for tid, task in list(rt.cluster.tasks.items()):
                inst = rt.fleet.instances.get(task.instance_id)
                if inst is None or inst.state.value != "running":
                    continue
                th = self._threads_by_task.get(tid)
                if th is None or (not th.is_alive() and counts["visible"] > 0):
                    self._spawn(tid, poll_interval)
            report = self.monitor.tick()
            idle_terms += len(report.idle_terminations)
            if report.finished:
                break
            rt.clock.sleep(monitor_interval)
        for t in self.threads:
            t.join(timeout=30.0)
        return RunSummary(
            jobs_done=sum(w.jobs_done for w in self.workers),
            jobs_skipped=sum(w.jobs_skipped for w in self.workers),
            jobs_failed=sum(w.jobs_failed for w in self.workers),
            dead_letters=0,
            preemptions=0,
            idle_terminations=idle_terms,
            ticks=ticks,
            wall_time=rt.clock.now() - start,
        )

"""Autoscaler — sizes the serving fleet to its load, without flapping.

The paper sizes the fleet once (``CLUSTER_MACHINES``) and leaves it; the
monitor only ever scales *down* (idle alarms, cheapest mode, teardown).
This control loop closes the other half: the monitor ticks it once per
poll, it reads demand from two deterministic signals, and it drives
``SpotFleet.modify_target`` + ``ECSCluster.update_desired_count``.

Signals
-------
- **queue depth**: serve leases report their shared request queue's
  ``visible + in_flight`` in heartbeat progress payloads (collected on
  the runtime's :class:`ProgressBoard`).  Every lease reports the *same*
  queue, so the policy takes the max over fresh reports — summing would
  multiply demand by the worker count.  With no fresh report (fleet
  still starting), the *job* queue's counts are the fallback.
- **SLO** (``autoscale=slo``): leases also report p99 TTFT (engine
  ticks) from their scheduler timing window; when the worst fresh p99
  exceeds ``autoscale_target_p99_ttft`` the fleet scales up regardless
  of queue depth.

**Role-split fleets**: when fresh reports carry ``role`` tags of
``prefill``/``decode`` (a disaggregated fleet), the policy sizes the
two pools independently — prefill off its request-queue backlog,
decode off the decode-queue backlog, active-slot pressure and (under
``slo``) decode-side p99 TTFT — and sums them into the single fleet
target.  Without role tags the legacy single-pool policy runs
unchanged.

Anti-flap machinery, all explicit knobs on :class:`~.config.DSConfig`:
hysteresis (inside the band ``(target/2, target]`` the fleet holds
rather than shrinking), separate scale-up / scale-down cooldowns (a
scale-down additionally waits out the *up* cooldown, so a spike
followed by quiet does not thrash), and a per-decision step bound
(``autoscale_max_step``).  Targets always clamp to
``[min_workers, max_workers]``.
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .clock import Clock
from .cluster import ECSCluster
from .config import DSConfig
from .fleet import SpotFleet
from .logs import LogGroup
from .queue import DurableQueue


class ProgressBoard:
    """Latest heartbeat progress payload per worker, with timestamps.

    Written from worker heartbeat paths (possibly many threads), read by
    the autoscaler on the monitor thread — hence the lock.  Stale
    entries (dead workers) age out via the ``fresh()`` window instead of
    requiring explicit deregistration.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._latest: Dict[str, Tuple[float, dict]] = {}

    def put(self, worker_id: str, payload: dict, now: float) -> None:
        with self._lock:
            self._latest[worker_id] = (now, dict(payload))

    def fresh(self, now: float, max_age: float) -> List[dict]:
        with self._lock:
            return [
                payload
                for t, payload in self._latest.values()
                if now - t <= max_age
            ]


@dataclass
class ScaleDecision:
    time: float
    current: int
    desired: int
    applied: bool
    reason: str


class Autoscaler:
    def __init__(
        self,
        cfg: DSConfig,
        queue: DurableQueue,
        fleet: SpotFleet,
        cluster: ECSCluster,
        *,
        clock: Clock,
        logs: Optional[LogGroup] = None,
        board: Optional[ProgressBoard] = None,
    ):
        self.cfg = cfg
        self.queue = queue
        self.fleet = fleet
        self.cluster = cluster
        self.clock = clock
        self.logs = logs
        self.board = board
        self._last_up = float("-inf")
        self._last_down = float("-inf")
        self.decisions: List[ScaleDecision] = []

    # ------------------------------------------------------------------ tick
    def tick(self) -> Optional[ScaleDecision]:
        cfg = self.cfg
        if cfg.autoscale == "off":
            return None
        now = self.clock.now()
        current = self.fleet.target_capacity
        max_age = max(2 * cfg.monitor_poll_seconds, 120.0)
        reports = [
            p
            for p in (self.board.fresh(now, max_age) if self.board else [])
            if p.get("kind") == "serve"
        ]
        if any(p.get("role") in ("prefill", "decode") for p in reports):
            # disaggregated fleet: per-role pools, summed into the one
            # fleet target (see _split_desired)
            desired, reason = self._split_desired(reports, current)
        else:
            if reports:
                backlog = max(int(p.get("backlog", 0)) for p in reports)
                signal = "reported"
            else:
                c = self.queue.counts()
                backlog = c["visible"] + c["in_flight"]
                signal = "job-queue"
            desired = math.ceil(backlog / max(1, cfg.autoscale_queue_per_worker))
            reason = f"{signal} backlog={backlog}"

            if cfg.autoscale == "slo" and reports:
                p99 = max(float(p.get("p99_ttft", 0.0)) for p in reports)
                target = cfg.autoscale_target_p99_ttft
                if p99 > target:
                    # SLO breach: step up as fast as the bound allows, even
                    # if the queue-depth policy thinks capacity suffices
                    desired = max(desired, current + cfg.autoscale_max_step)
                    reason = f"slo breach p99_ttft={p99:.1f}>{target:.1f}"
                elif p99 > target / 2 and desired < current:
                    # hysteresis band: latency is within SLO but not by a
                    # 2x margin — hold rather than shrink into a breach
                    desired = current
                    reason = f"slo hold p99_ttft={p99:.1f} in ({target/2:.1f},{target:.1f}]"

        desired = max(cfg.min_workers, min(cfg.max_workers, desired))
        # per-decision step bound
        desired = max(current - cfg.autoscale_max_step,
                      min(current + cfg.autoscale_max_step, desired))

        applied = False
        if desired > current:
            if now - self._last_up >= cfg.autoscale_up_cooldown_seconds:
                self._apply(desired)
                self._last_up = now
                applied = True
            else:
                reason += " (up-cooldown)"
        elif desired < current:
            # a scale-down also waits out the up-cooldown so a spike
            # followed by one quiet poll cannot flap the fleet
            if now - max(self._last_up, self._last_down) >= (
                cfg.autoscale_down_cooldown_seconds
            ):
                self._apply(desired)
                self._last_down = now
                applied = True
            else:
                reason += " (down-cooldown)"
        decision = ScaleDecision(
            time=now, current=current, desired=desired,
            applied=applied, reason=reason,
        )
        self.decisions.append(decision)
        if applied and self.logs is not None:
            self.logs.put(
                "autoscaler",
                f"scale {current} -> {desired} ({reason})",
            )
        return decision

    # ------------------------------------- disaggregated per-role pools
    def _split_desired(self, reports: List[dict],
                       current: int) -> Tuple[int, str]:
        """Size a role-split fleet: two pools, one fleet target.

        The prefill pool is demand-driven off the *request-queue* backlog
        (prefill leases report it): prompts waiting to be prefilled are
        the only signal that pool can act on.  The decode pool is sized
        off the *decode-queue* backlog (decode leases report THEIR
        queue) and active-slot pressure, and under ``autoscale=slo``
        additionally steps up past any queue-depth answer when the worst
        fresh decode p99 TTFT breaches the target — TTFT on a split
        fleet is dominated by the decode side's admission latency.  Each
        pool with live leases keeps a floor of one worker (a pipeline
        with either stage empty serves nothing).  The sum feeds the
        caller's shared clamp/step/cooldown machinery; reasons carry the
        per-role breakdown so scale decisions stay auditable."""
        cfg = self.cfg
        qpw = max(1, cfg.autoscale_queue_per_worker)
        pre = [p for p in reports if p.get("role") == "prefill"]
        dec = [p for p in reports if p.get("role") == "decode"]
        uni = [p for p in reports if p.get("role", "unified") == "unified"]

        pre_backlog = max((int(p.get("backlog", 0)) for p in pre), default=0)
        want_pre = math.ceil(pre_backlog / qpw)
        if pre:
            want_pre = max(1, want_pre)

        dec_backlog = max((int(p.get("backlog", 0)) for p in dec), default=0)
        dec_active = sum(int(p.get("active", 0)) for p in dec)
        want_dec = max(
            math.ceil(dec_backlog / qpw), math.ceil(dec_active / qpw)
        )
        if dec:
            want_dec = max(1, want_dec)

        # a mixed fleet (unified leases riding along) sizes its legacy
        # share exactly as the non-split policy would
        uni_backlog = max((int(p.get("backlog", 0)) for p in uni), default=0)
        want_uni = math.ceil(uni_backlog / qpw)

        desired = want_pre + want_dec + want_uni
        reason = f"role-split prefill={want_pre} decode={want_dec}"
        if uni:
            reason += f" unified={want_uni}"

        if cfg.autoscale == "slo" and dec:
            p99 = max(float(p.get("p99_ttft", 0.0)) for p in dec)
            target = cfg.autoscale_target_p99_ttft
            if p99 > target:
                # step the DECODE pool up by the bound from its live
                # size; the prefill share is preserved on top
                desired = max(
                    desired, want_pre + len(dec) + cfg.autoscale_max_step
                )
                reason = (
                    f"decode slo breach p99_ttft={p99:.1f}>{target:.1f} "
                    f"(prefill={want_pre})"
                )
            elif p99 > target / 2 and desired < current:
                desired = current
                reason = (
                    f"decode slo hold p99_ttft={p99:.1f} "
                    f"in ({target/2:.1f},{target:.1f}]"
                )
        return desired, reason

    def _apply(self, desired: int) -> None:
        self.fleet.modify_target(desired)
        svc = f"{self.cfg.app_name}Service"
        if svc in self.cluster.services:
            self.cluster.update_desired_count(
                svc, desired * self.cfg.tasks_per_machine
            )

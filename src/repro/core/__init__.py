"""repro.core — the Distributed-Something control plane.

The paper's contribution as a composable library: durable queue with SQS
semantics, simulated spot fleet, ECS-style placement, CloudWatch-style
monitor, the generic worker template, and the four-command runtime.
"""

from .clock import Clock, VirtualClock, WallClock
from .cluster import ECSCluster, Service, Task, TaskDefinition
from .config import MACHINE_CATALOGUE, DSConfig, FleetFile, MachineType, load_config, load_fleet_file
from .fleet import Instance, InstanceState, SpotFleet, SpotMarket
from .jobs import JobFile, load_job_file, step_span_job_file
from .logs import LogGroup, MetricRegistry
from .monitor import Monitor, MonitorReport
from .queue import DurableQueue, Message
from .runtime import DSRuntime, RunSummary, SimRunner, ThreadRunner
from .storage import ObjectInfo, ObjectStore
from .worker import (PAYLOAD_REGISTRY, NotReady, Preempted, Worker, WorkerContext,
                     check_if_done, register_payload)

"""Clock abstraction so the DS control plane is deterministically testable.

The paper's control plane is driven by wall-clock behaviours (SQS message
visibility timeouts, CloudWatch "CPU < 1% for 15 minutes" alarms, the
monitor's once-per-minute poll).  We route every time read/sleep through a
``Clock`` so tests and the simulation runner can use a ``VirtualClock``
and advance time explicitly, while real local runs use ``WallClock``.
"""

from __future__ import annotations

import threading
import time


class Clock:
    """Interface: ``now()`` returns seconds, ``sleep(dt)`` advances/blocks."""

    def now(self) -> float:
        raise NotImplementedError

    def sleep(self, seconds: float) -> None:
        raise NotImplementedError


class WallClock(Clock):
    def now(self) -> float:
        return time.time()

    def sleep(self, seconds: float) -> None:
        time.sleep(seconds)


class VirtualClock(Clock):
    """Deterministic clock; ``sleep`` advances time instead of blocking.

    Thread-safe so the thread runner can also use it in stress tests.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            return self._t

    def sleep(self, seconds: float) -> None:
        self.advance(seconds)

    def advance(self, seconds: float) -> float:
        if seconds < 0:
            raise ValueError("cannot advance clock backwards")
        with self._lock:
            self._t += float(seconds)
            return self._t

"""Job files — the paper's Step 2 (``submitJob``).

A job file is shared metadata plus a ``groups`` list; DS enqueues one SQS
message per group, each message carrying ``shared ∪ group``.  We keep that
exact contract: grouping choice is the user's parallelism knob ("many
small machines ... or a large machine to perform a single task").

For the training "Something", a group is typically a *step span*
(``{"start_step": 0, "num_steps": 50}``) or a hyper-parameter setting;
for serving it is a request batch; for eval a data shard.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List


@dataclass
class JobFile:
    shared: Dict[str, Any] = field(default_factory=dict)
    groups: List[Dict[str, Any]] = field(default_factory=list)

    def expand(self) -> List[Dict[str, Any]]:
        """One message body per group: shared keys overlaid by group keys."""
        out = []
        for i, group in enumerate(self.groups):
            body = dict(self.shared)
            body.update(group)
            body.setdefault("group_index", i)
            out.append(body)
        return out

    def to_json(self) -> str:
        d = dict(self.shared)
        d["groups"] = self.groups
        return json.dumps(d, indent=2, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "JobFile":
        d = dict(d)
        groups = d.pop("groups", [])
        if not isinstance(groups, list):
            raise ValueError("'groups' must be a list")
        norm = []
        for g in groups:
            if isinstance(g, dict):
                norm.append(g)
            else:
                # the paper allows plain strings appended from a txt file
                norm.append({"group": g})
        return cls(shared=d, groups=norm)

    @classmethod
    def from_json(cls, text: str) -> "JobFile":
        return cls.from_dict(json.loads(text))


def load_job_file(path: str) -> JobFile:
    with open(path) as f:
        return JobFile.from_json(f.read())


def step_span_job_file(
    *,
    arch: str,
    total_steps: int,
    span: int,
    run: str = "run0",
    shared: Dict[str, Any] | None = None,
) -> JobFile:
    """Build a training job file whose groups are contiguous step spans.

    This is the canonical decomposition for ``distributed-train``:
    checkpoint-delimited spans make every job idempotent and resumable —
    the paper's CHECK_IF_DONE generalized to training state.  Each group
    carries its ``output_prefix`` so the generic worker's done-check can
    skip completed spans on resubmission.
    """
    groups = [
        {
            "start_step": s,
            "num_steps": min(span, total_steps - s),
            "output_prefix": f"runs/{run}/spans/{s:06d}-{min(s + span, total_steps):06d}",
        }
        for s in range(0, total_steps, span)
    ]
    base = {"arch": arch, "total_steps": total_steps, "run": run}
    if shared:
        base.update(shared)
    return JobFile(shared=base, groups=groups)

"""The monitor — the paper's optional fourth command, plus the per-instance
idle alarms that exist even without it.

Responsibilities (paper Step 4):

- poll the queue "once per minute" for visible/in-flight counts;
- evaluate idle alarms: an instance whose tasks have produced no heartbeat
  for ``idle_alarm_seconds`` ("CPU < 1% for 15 consecutive minutes, almost
  always the result of a crashed machine") is terminated and — in normal
  mode — replaced by the fleet's back-fill;
- hourly housekeeping: delete alarms of instances terminated in the last
  24 h (here: drop their liveness records);
- when the queue is fully drained (0 visible, 0 in-flight): downscale the
  ECS service, cancel the spot fleet, purge queues, export logs to the
  object store, and delete task definitions — the teardown sequence;
- "cheapest" mode: after a grace period, drop the fleet *target* to 1 and
  stop replacing terminated instances.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from .clock import Clock, WallClock
from .cluster import ECSCluster
from .config import DSConfig
from .fleet import InstanceState, SpotFleet
from .logs import LogGroup, MetricRegistry
from .queue import DurableQueue
from .storage import ObjectStore

CHEAPEST_GRACE_SECONDS = 15 * 60.0  # paper: downscale 15 min after engaged


@dataclass
class MonitorReport:
    time: float
    visible: int
    in_flight: int
    dead: int
    running_instances: int
    pending_instances: int
    idle_terminations: List[str] = field(default_factory=list)
    downscaled: bool = False
    finished: bool = False
    # chaos faults fired this poll ("kind:target") and the autoscaler's
    # applied target change (None = held / autoscaling off)
    chaos_events: List[str] = field(default_factory=list)
    autoscaled_to: Optional[int] = None


class Monitor:
    def __init__(
        self,
        cfg: DSConfig,
        queue: DurableQueue,
        fleet: SpotFleet,
        cluster: ECSCluster,
        logs: LogGroup,
        metrics: MetricRegistry,
        store: ObjectStore,
        *,
        clock: Optional[Clock] = None,
        cheapest: bool = False,
        autoscaler=None,
        chaos=None,
    ):
        self.cfg = cfg
        self.queue = queue
        self.fleet = fleet
        self.cluster = cluster
        self.logs = logs
        self.metrics = metrics
        self.store = store
        self.clock = clock or WallClock()
        self.cheapest = cheapest
        self.autoscaler = autoscaler
        self.chaos = chaos
        self.started_at = self.clock.now()
        self.finished = False
        self._cheapest_applied = False
        self._last_hourly = self.started_at
        self._alarm_records: dict = {}
        self.history: List[MonitorReport] = []

    # ------------------------------------------------------------------ tick
    def tick(self) -> MonitorReport:
        """One monitor poll (the paper's once-per-minute check)."""
        now = self.clock.now()
        # fire scheduled chaos first: a fault injected this poll must be
        # visible to the idle alarms / autoscaler evaluated below, same
        # as one that happened between polls
        if self.chaos is not None:
            report_chaos = [
                f"{r.kind}:{r.target}" for r in self.chaos.tick()
            ]
        else:
            report_chaos = []
        counts = self.queue.counts()
        report = MonitorReport(
            time=now,
            visible=counts["visible"],
            in_flight=counts["in_flight"],
            dead=counts["dead"],
            running_instances=len(self.fleet.running()),
            pending_instances=len(self.fleet.pending()),
            chaos_events=report_chaos,
        )

        # -- idle alarms -----------------------------------------------------
        for inst in self.fleet.running():
            idle_for = now - max(inst.last_heartbeat, inst.ready_time)
            if idle_for >= self.cfg.idle_alarm_seconds:
                self.fleet.terminate_instance(inst.id, reason="idle-alarm")
                self.logs.put(
                    "monitor",
                    f"idle alarm fired for {inst.id} (idle {idle_for:.0f}s); terminated",
                )
                report.idle_terminations.append(inst.id)
        self.cluster.reap_dead_tasks(self.fleet)

        # -- hourly housekeeping ------------------------------------------------
        if now - self._last_hourly >= 3600.0:
            cutoff = now - 24 * 3600.0
            for iid, inst in list(self.fleet.instances.items()):
                if (
                    inst.state == InstanceState.TERMINATED
                    and inst.terminate_time is not None
                    and inst.terminate_time >= cutoff
                ):
                    self._alarm_records.pop(iid, None)
            self._last_hourly = now

        # -- cheapest mode -------------------------------------------------------
        if (
            self.cheapest
            and not self._cheapest_applied
            and now - self.started_at >= CHEAPEST_GRACE_SECONDS
        ):
            self.fleet.modify_target(min(self.fleet.target_capacity, 1))
            self.fleet.replace_on_terminate = False
            self._cheapest_applied = True
            self.logs.put("monitor", "cheapest mode: fleet target downscaled to 1")

        # -- autoscaling ---------------------------------------------------------
        if self.autoscaler is not None and not self.finished:
            decision = self.autoscaler.tick()
            if decision is not None and decision.applied:
                report.autoscaled_to = decision.desired

        # -- teardown when drained --------------------------------------------------
        if counts["visible"] == 0 and counts["in_flight"] == 0 and not self.finished:
            self._teardown()
            report.downscaled = True
            report.finished = True

        self.metrics.gauge("queue.visible", counts["visible"])
        self.metrics.gauge("queue.in_flight", counts["in_flight"])
        self.metrics.gauge("fleet.running", report.running_instances)
        self.history.append(report)
        return report

    def run(self, max_ticks: int = 10_000) -> MonitorReport:
        """Poll until drained (tick cadence = ``monitor_poll_seconds``)."""
        report = self.tick()
        ticks = 1
        while not report.finished and ticks < max_ticks:
            self.clock.sleep(self.cfg.monitor_poll_seconds)
            report = self.tick()
            ticks += 1
        return report

    # ------------------------------------------------------------------ teardown
    def _sweep_queue(self) -> int:
        """Batched straggler sweep: messages that became visible between
        the drain check and teardown (e.g. a preempted worker's lease
        expiring mid-poll) are claimed with ``receive_batch`` and
        acknowledged with ``delete_batch`` — one transaction per batch
        instead of a lock + SQL round-trip per message — so their ids are
        logged before the final purge wipes the tables."""
        swept = 0
        while True:
            batch = self.queue.receive_batch(32)
            if not batch:
                break
            for m in batch:
                self.logs.put(
                    "monitor", f"teardown sweep: acked straggler {m.id}"
                )
            swept += self.queue.delete_batch(batch)
        return swept

    def _sweep_kvprefix(self) -> int:
        """TTL-sweep the cross-host KV prefix pages (``kvprefix/``) when
        the config opts in: without it the content-addressed store grows
        across runs until an operator sweeps by hand.  Pages are
        immutable and re-publishable, so expiry is always safe; workers
        racing the sweep see a plain fetch miss."""
        ttl = getattr(self.cfg, "kvprefix_ttl_seconds", None)
        if ttl is None:
            return 0
        from repro.serving.prefix_store import PrefixStore

        # the namespace only keys page hashes; sweeping is by key prefix
        # and mtime, so any namespace value works here
        return PrefixStore(self.store, namespace="sweep").sweep(float(ttl))

    def _teardown(self) -> None:
        svc_name = f"{self.cfg.app_name}Service"
        if svc_name in self.cluster.services:
            self.cluster.update_desired_count(svc_name, 0)
            self.cluster.deregister_service(svc_name)
        self.fleet.cancel(terminate_instances=True)
        self.cluster.reap_dead_tasks(self.fleet)
        swept = self._sweep_queue()
        if swept:
            self.logs.put("monitor", f"teardown sweep acked {swept} stragglers")
        pages = self._sweep_kvprefix()
        if pages:
            self.logs.put(
                "monitor", f"teardown sweep deleted {pages} expired kvprefix pages"
            )
        self.queue.purge()  # in-flight remnants + dead letters
        n = self.logs.export(self.store, f"logs/{self.cfg.app_name}")
        self.logs.put("monitor", f"teardown complete; exported {n} log streams")
        self.finished = True

"""The generic worker — the paper's ``worker/generic-worker.py``.

Loop contract (paper Step 3, automatic actions 5–6):

1. poll the queue; if no visible jobs after a few polls, shut down;
2. pre-flight ``CHECK_IF_DONE``: if the output prefix already holds
   ``EXPECTED_NUMBER_FILES`` objects of at least ``MIN_FILE_SIZE_BYTES``
   (optionally containing ``NECESSARY_STRING`` in the key), acknowledge
   without recomputing — this is what makes whole-run resubmission cheap;
3. run the payload with a heartbeat context; heartbeats extend the SQS
   visibility lease so long jobs are not stolen, and raise
   :class:`Preempted` the moment the instance is terminated so state is
   abandoned mid-step exactly like a real spot kill;
4. on success acknowledge (delete) the message; on failure do nothing —
   the visibility timeout re-delivers, and the DLQ catches poison jobs.

Payloads are looked up in a registry by name (the ``DOCKERHUB_TAG``
analogue): signature ``payload(job: dict, ctx: WorkerContext) -> dict``.
"""

from __future__ import annotations

import hashlib
import json
import random
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from .clock import Clock, WallClock
from .cluster import TaskDefinition
from .logs import LogGroup, MetricRegistry
from .queue import DurableQueue, Message
from .storage import ObjectStore


class Preempted(Exception):
    """Raised inside a payload when the hosting instance is terminated."""


class NotReady(Exception):
    """Raised by a payload whose prerequisite is not yet available (e.g. a
    step-span job waiting for an earlier span's checkpoint).  The message
    is released back to the queue after ``retry_in`` seconds without
    consuming retry budget."""

    def __init__(self, msg: str, retry_in: float = 10.0):
        super().__init__(msg)
        self.retry_in = retry_in


class LeaseYield(Exception):
    """Raised by a long-lived payload (a serving lease) that has spent
    its per-claim slice budget: the message is *released* (retry budget
    refunded) so the same or another worker resumes it, keeping every
    worker's per-tick work bounded and letting the fleet re-balance
    leases under churn."""

    def __init__(self, msg: str, retry_in: float = 0.0):
        super().__init__(msg)
        self.retry_in = retry_in


def backoff_delay(
    base: float, attempt: int, *, cap: float, key: str, jitter: float = 0.5
) -> float:
    """Capped exponential backoff with deterministic jitter.

    ``min(cap, base * 2**(attempt-1))``, scaled down by up to ``jitter``
    fraction drawn from ``random.Random(f"{key}#{attempt}")`` — the same
    (key, attempt) pair always yields the same delay (schedules replay
    exactly), while distinct keys de-synchronize a thundering herd of
    requeued jobs that would otherwise retry in lockstep."""
    a = max(1, int(attempt))
    delay = min(float(cap), float(base) * (2.0 ** (a - 1)))
    if jitter and delay > 0:
        delay *= 1.0 - jitter * random.Random(f"{key}#{a}").random()
    return delay


def _stable_key(msg: Message) -> str:
    """A run-to-run stable jitter key for a message: its *content* hash.
    Message ids are uuid4 (fresh every run), so keying jitter on them
    would make retry schedules unreproducible."""
    try:
        blob = json.dumps(msg.body, sort_keys=True)
    except (TypeError, ValueError):
        return str(msg.id)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


PAYLOAD_REGISTRY: Dict[str, Callable[[dict, "WorkerContext"], dict]] = {}


def register_payload(name: str):
    """Decorator: register a "Something" under ``name``."""

    def deco(fn):
        PAYLOAD_REGISTRY[name] = fn
        return fn

    return deco


@dataclass
class WorkerContext:
    """Everything a payload may touch, plus the heartbeat channel."""

    store: ObjectStore
    logs: LogGroup
    metrics: MetricRegistry
    clock: Clock
    task: TaskDefinition
    worker_id: str
    message: Optional[Message] = None
    queue: Optional[DurableQueue] = None
    # liveness wiring
    is_terminated: Callable[[], bool] = lambda: False
    on_heartbeat: Callable[[], None] = lambda: None
    # spot-revocation notice: True once the hosting instance has been
    # warned of termination — the payload should drain, not crash
    is_revoked: Callable[[], bool] = lambda: False
    # structured progress channel (autoscaler telemetry): payloads push
    # small dicts, the runner forwards them to the runtime's ProgressBoard
    on_progress: Callable[[dict], None] = lambda payload: None
    visibility: float = 120.0
    _last_extension: float = field(default=0.0)

    def heartbeat(self, progress: Optional[str] = None) -> None:
        """Payloads call this between units of work (e.g. every train step)."""
        if self.is_terminated():
            raise Preempted(f"instance hosting {self.worker_id} terminated")
        self.on_heartbeat()
        now = self.clock.now()
        # extend the lease when half the visibility window has elapsed
        if self.queue is not None and self.message is not None:
            if now - self._last_extension > self.visibility / 2:
                self.queue.change_visibility(self.message, self.visibility)
                self._last_extension = now
        if progress:
            self.logs.put(self.worker_id, progress)

    def revoked(self) -> bool:
        """True once the hosting instance holds a spot-revocation notice."""
        return self.is_revoked()

    def report_progress(self, payload: dict) -> None:
        """Publish a structured progress payload (autoscaler telemetry)."""
        self.on_progress(payload)

    def log(self, message: str, **fields) -> None:
        self.logs.put(self.worker_id, message, **fields)


def check_if_done(store: ObjectStore, td: TaskDefinition, output_prefix: str) -> bool:
    """The paper's done-check, verbatim semantics."""
    if not td.check_if_done:
        return False
    n = 0
    for info in store.list(output_prefix):
        if info.size < td.min_file_size_bytes:
            continue
        if td.necessary_string and td.necessary_string not in info.key:
            continue
        n += 1
    return n >= td.expected_number_files


class Worker:
    """One Docker-container-equivalent consuming jobs from the queue."""

    def __init__(
        self,
        worker_id: str,
        queue: DurableQueue,
        store: ObjectStore,
        logs: LogGroup,
        metrics: MetricRegistry,
        task: TaskDefinition,
        *,
        clock: Optional[Clock] = None,
        visibility: float = 120.0,
        empty_polls_before_shutdown: int = 3,
        is_terminated: Callable[[], bool] = lambda: False,
        on_heartbeat: Callable[[], None] = lambda: None,
        is_revoked: Callable[[], bool] = lambda: False,
        on_progress: Callable[[dict], None] = lambda payload: None,
        prefetch: int = 1,
    ):
        self.worker_id = worker_id
        self.queue = queue
        self.store = store
        self.logs = logs
        self.metrics = metrics
        self.task = task
        self.clock = clock or WallClock()
        self.visibility = visibility
        self.empty_polls_before_shutdown = empty_polls_before_shutdown
        self.is_terminated = is_terminated
        self.on_heartbeat = on_heartbeat
        self.is_revoked = is_revoked
        self.on_progress = on_progress
        # prefetch > 1: claim a batch of jobs in ONE queue transaction
        # (receive_batch) and drain it locally — high-fanout fleets stop
        # paying a lock + SQL round-trip per job.  Buffered jobs hold
        # their visibility lease; an unprocessed buffer simply resurfaces
        # after the timeout (at-least-once, same as a crashed worker).
        self.prefetch = max(1, int(prefetch))
        self._buffer: list = []
        self.jobs_done = 0
        self.jobs_failed = 0
        self.jobs_skipped = 0
        self.jobs_not_ready = 0
        self.jobs_yielded = 0
        # NotReady retries per message (keyed by id): release() refunds
        # receive_count, so the message's own counter cannot number the
        # attempts that exponential backoff needs
        self._notready_attempts: Dict[str, int] = {}

    # -- single-message processing (used by both runners) --------------------
    def process_one(self) -> Optional[str]:
        """Receive and process at most one message.

        Returns "done"/"skipped"/"failed"/"preempted" or ``None`` if the
        queue had no visible message.
        """
        if self.is_terminated():
            return "preempted"
        if not self._buffer:
            self._buffer = self.queue.receive_batch(self.prefetch, self.visibility)
        msg = self._buffer.pop(0) if self._buffer else None
        if msg is None:
            return None
        job = msg.body
        ctx = WorkerContext(
            store=self.store,
            logs=self.logs,
            metrics=self.metrics,
            clock=self.clock,
            task=self.task,
            worker_id=self.worker_id,
            message=msg,
            queue=self.queue,
            is_terminated=self.is_terminated,
            on_heartbeat=self.on_heartbeat,
            is_revoked=self.is_revoked,
            on_progress=self.on_progress,
            visibility=self.visibility,
        )
        ctx._last_extension = self.clock.now()
        output_prefix = job.get("output_prefix", "")
        try:
            if output_prefix and check_if_done(self.store, self.task, output_prefix):
                ctx.log(f"CHECK_IF_DONE: {output_prefix} already complete, skipping")
                self.queue.delete(msg)
                self.jobs_skipped += 1
                return "skipped"
            payload = PAYLOAD_REGISTRY.get(self.task.payload)
            if payload is None:
                raise KeyError(f"no payload registered under {self.task.payload!r}")
            if self.task.seconds_to_start:
                # SECONDS_TO_START: stagger copies to avoid memory spikes
                self.clock.sleep(self.task.seconds_to_start)
            result = payload(job, ctx)
            ctx.log("job complete", result=result)
            self.queue.delete(msg)
            self.jobs_done += 1
            self._notready_attempts.pop(msg.id, None)
            return "done"
        except Preempted:
            ctx.log("preempted mid-job; message will re-surface via visibility timeout")
            return "preempted"
        except LeaseYield as e:
            # a long-lived lease handing its slice back: release (budget
            # refunded — yielding is routine, not failure) and let the
            # fleet re-claim it.  No log line: slices recur every tick.
            self.queue.release(msg, e.retry_in)
            self.jobs_yielded += 1
            return "yielded"
        except NotReady as e:
            # capped exponential backoff + deterministic content-keyed
            # jitter: after a revocation requeues a herd of waiting jobs,
            # their retries spread out instead of hammering in lockstep
            attempt = self._notready_attempts.get(msg.id, 0) + 1
            self._notready_attempts[msg.id] = attempt
            delay = backoff_delay(
                e.retry_in, attempt, cap=self.visibility, key=_stable_key(msg)
            )
            ctx.log(
                f"job not ready ({e}); released for retry in {delay:.1f}s "
                f"(attempt {attempt})"
            )
            self.queue.release(msg, delay)
            self.jobs_not_ready += 1
            return "not_ready"
        except Exception as e:  # noqa: BLE001 - worker must survive payload bugs
            ctx.log(
                f"job failed (attempt {msg.receive_count}/{self.queue.max_receive_count}): {e}",
                traceback=traceback.format_exc(limit=20),
            )
            # fast-return with backoff: a failed job should not sit out its
            # full (long) processing lease — e.g. a step-span waiting on a
            # prerequisite checkpoint retries as earlier spans land.
            # Exponential in the receive count (the message's own attempt
            # number survives worker crashes), capped at the visibility,
            # jittered deterministically by content.
            backoff = backoff_delay(
                5.0, msg.receive_count, cap=self.visibility, key=_stable_key(msg)
            )
            self.queue.change_visibility(msg, backoff)
            self.jobs_failed += 1
            self._notready_attempts.pop(msg.id, None)
            return "failed"

    # -- the full loop (thread runner) ------------------------------------------
    def run(self, poll_interval: float = 0.05) -> None:
        empty = 0
        while not self.is_terminated():
            outcome = self.process_one()
            if outcome is None:
                empty += 1
                if empty >= self.empty_polls_before_shutdown:
                    # "If SQS tells them there are no visible jobs then they
                    # shut themselves down."
                    self.logs.put(self.worker_id, "queue empty; shutting down")
                    return
                self.clock.sleep(poll_interval)
            elif outcome == "preempted":
                return
            else:
                empty = 0

"""Deterministic seeded fault injection for the simulated fleet.

The paper treats failure as routine ("spot prices rising above your
maximum bid, machine crashes, etc.") and recovers through the queue's
visibility timeout.  This module makes failure a *scheduled, replayable*
event so the serving tier's churn behaviour can be asserted, not hoped
for.  Six fault kinds:

- ``kill`` — terminate an instance with no warning (a machine crash):
  the next heartbeat from any task on it raises ``Preempted`` and its
  in-flight work resurfaces via visibility timeouts;
- ``revoke`` — deliver a spot-revocation *notice*: ``Instance.revoke_at``
  is set ``notice_seconds`` in the future, the hosting workers observe
  it through ``WorkerContext.revoked()`` and gracefully drain (stop
  admitting, flush prefix publications, requeue in-flight requests),
  and the fleet terminates the instance when the deadline passes;
- ``delay_heartbeat`` — suppress an instance's heartbeat record for
  ``duration`` seconds (a wedged-but-alive machine): the monitor's idle
  alarm eventually fires exactly as for a crashed host;
- ``truncate_blob`` — corrupt one published ``kvprefix/`` page in the
  object store (truncate to half length): hydrating workers must treat
  it as a fetch miss, never crash;
- ``flaky_storage`` — open a ``duration``-second window during which the
  shared object store's ``put_bytes``/``get_bytes`` raise a transient
  ``ConnectionError`` on the *first* attempt per distinct key (then
  succeed), optionally scoped to a key prefix: exercises every caller's
  retry/backoff discipline without ever losing data;
- ``flaky_queue`` — same window for the durable queue's consumer side
  (``receive_batch`` / ``delete``), injected through the queue module's
  per-path fault hook so every lease's own handle on the shared sqlite
  file is faulted, not just one instance.

Everything is deterministic: events carry explicit virtual-time (``at``)
or heartbeat-count (``after_beats``) triggers, victims are an index into
the *sorted* running-instance list, and the helper schedule builders
draw from ``random.Random(seed)`` only.  Two runs with the same seeds
produce the same ``log``.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .clock import Clock
from .fleet import Instance, SpotFleet
from .logs import LogGroup
from .queue import DurableQueue, install_fault_hook
from .storage import ObjectStore


@dataclass
class ChaosEvent:
    """One scheduled fault.  Exactly one of ``at`` (virtual time) or
    ``after_beats`` (cumulative heartbeat count — fires *mid-slice*,
    between two heartbeats of a running payload) should be set."""

    kind: str  # "kill" | "revoke" | "delay_heartbeat" | "truncate_blob"
    #            | "flaky_storage" | "flaky_queue"
    at: Optional[float] = None
    after_beats: Optional[int] = None
    victim: int = 0  # index into sorted eligible targets (mod len)
    notice_seconds: float = 120.0  # revoke: warning before termination
    duration: float = 0.0  # delay_heartbeat / flaky_*: fault window length
    scope: str = ""  # flaky_storage: comma-separated key prefixes ("" = all)

    def __post_init__(self):
        if self.kind not in (
            "kill",
            "revoke",
            "delay_heartbeat",
            "truncate_blob",
            "flaky_storage",
            "flaky_queue",
        ):
            raise ValueError(f"unknown chaos event kind {self.kind!r}")
        if (self.at is None) == (self.after_beats is None):
            raise ValueError("exactly one of at/after_beats must be set")


@dataclass
class ChaosRecord:
    """What actually happened (the determinism test compares these)."""

    kind: str
    target: str
    time: float


class ChaosMonkey:
    """Fires :class:`ChaosEvent` s against a fleet, deterministically.

    ``tick()`` is called by the monitor once per poll (time-triggered
    events); ``on_beat(inst)`` is called from the runner's heartbeat
    path (beat-triggered events, which kill a worker *mid-slice*);
    ``allow_heartbeat(inst)`` gates liveness recording so a
    ``delay_heartbeat`` fault looks exactly like a wedged host.  An
    event whose trigger has passed but which has no eligible target yet
    (e.g. a revoke while nothing is running) stays pending and retries.
    """

    def __init__(
        self,
        fleet: SpotFleet,
        clock: Clock,
        *,
        seed: int = 0,
        events: List[ChaosEvent] = (),
        store: Optional[ObjectStore] = None,
        logs: Optional[LogGroup] = None,
        queue: Optional[DurableQueue] = None,
    ):
        self.fleet = fleet
        self.clock = clock
        self.seed = int(seed)
        self.rng = random.Random(self.seed)
        self.pending: List[ChaosEvent] = list(events)
        self.store = store
        self.logs = logs
        self.queue = queue
        self.log: List[ChaosRecord] = []
        self.counters: Dict[str, int] = {
            "kills": 0,
            "revocations": 0,
            "heartbeat_delays": 0,
            "blobs_truncated": 0,
            "storage_faults": 0,
            "queue_faults": 0,
        }
        self._beats = 0
        self._suppress: Dict[str, float] = {}  # instance id -> until
        # flaky_storage state: the store's put/get are wrapped lazily at
        # first arming and never unwrapped — the wrapper is a pass-through
        # outside the window.  Originals are kept so the monkey's own
        # truncate_blob path bypasses its own faults.
        self._storage_until = 0.0
        self._storage_scope: tuple = ()
        self._storage_failed: set = set()  # (op, key) faulted this window
        self._storage_orig_get = None
        self._storage_orig_put = None
        # flaky_queue state (per-path hook registered lazily at first arming)
        self._queue_until = 0.0
        self._queue_failed: set = set()  # ops faulted this window
        self._queue_hooked = False

    # ------------------------------------------------------- schedule builders
    @classmethod
    def revocation_drill(
        cls,
        fleet: SpotFleet,
        clock: Clock,
        *,
        seed: int,
        n_revocations: int,
        start: float,
        spacing: float,
        notice_seconds: float,
        store: Optional[ObjectStore] = None,
        logs: Optional[LogGroup] = None,
    ) -> "ChaosMonkey":
        """A seeded drill: ``n_revocations`` spot-revocation notices from
        ``start``, roughly ``spacing`` apart (seeded jitter), each with
        ``notice_seconds`` of warning.  Same seed => same schedule."""
        rng = random.Random(seed)
        events, t = [], float(start)
        for _ in range(int(n_revocations)):
            events.append(
                ChaosEvent(
                    kind="revoke",
                    at=t,
                    victim=rng.randrange(1 << 16),
                    notice_seconds=float(notice_seconds),
                )
            )
            t += spacing * (0.5 + rng.random())
        return cls(fleet, clock, seed=seed, events=events, store=store, logs=logs)

    @classmethod
    def recovery_drill(
        cls,
        fleet: SpotFleet,
        clock: Clock,
        *,
        seed: int,
        n_revocations: int,
        start: float,
        spacing: float,
        notice_seconds: float,
        flaky_duration: float = 0.0,
        flaky_scope: str = "",
        store: Optional[ObjectStore] = None,
        logs: Optional[LogGroup] = None,
        queue: Optional[DurableQueue] = None,
    ) -> "ChaosMonkey":
        """The revocation drill plus flaky infrastructure: alongside each
        revocation notice, a ``flaky_duration``-second window of transient
        storage and queue faults opens at the notice time — so every drain
        (checkpoint puts, page publications, requeue acks) and every
        resume (checkpoint gets, hydration fetches) runs against first-
        attempt failures and must survive via retry.  Same seed => same
        schedule, including the flaky windows."""
        rng = random.Random(seed)
        events, t = [], float(start)
        for _ in range(int(n_revocations)):
            events.append(
                ChaosEvent(
                    kind="revoke",
                    at=t,
                    victim=rng.randrange(1 << 16),
                    notice_seconds=float(notice_seconds),
                )
            )
            if flaky_duration > 0:
                events.append(
                    ChaosEvent(
                        kind="flaky_storage",
                        at=t,
                        duration=float(flaky_duration),
                        scope=flaky_scope,
                    )
                )
                events.append(
                    ChaosEvent(kind="flaky_queue", at=t, duration=float(flaky_duration))
                )
            t += spacing * (0.5 + rng.random())
        return cls(
            fleet,
            clock,
            seed=seed,
            events=events,
            store=store,
            logs=logs,
            queue=queue,
        )

    # ---------------------------------------------------------------- triggers
    def tick(self) -> List[ChaosRecord]:
        """Fire every time-triggered event whose moment has come."""
        now = self.clock.now()
        return self._fire_due(
            lambda ev: ev.at is not None and now >= ev.at
        )

    def on_beat(self, inst: Instance) -> None:
        """Advance the global heartbeat counter; fire beat-triggered
        events against the instance that is beating *right now* (the
        only target that is provably mid-payload)."""
        self._beats += 1
        for ev in list(self.pending):
            if ev.after_beats is not None and self._beats >= ev.after_beats:
                if self._apply(ev, target=inst):
                    self.pending.remove(ev)

    def allow_heartbeat(self, inst: Instance) -> bool:
        """False while ``inst`` is under a delay_heartbeat fault (the
        runner then skips recording liveness, so the idle alarm sees a
        silent host)."""
        until = self._suppress.get(inst.id)
        if until is None:
            return True
        if self.clock.now() >= until:
            del self._suppress[inst.id]
            return True
        return False

    # ---------------------------------------------------------------- firing
    def _fire_due(self, due) -> List[ChaosRecord]:
        fired: List[ChaosRecord] = []
        still: List[ChaosEvent] = []
        for ev in self.pending:
            if due(ev) and self._apply(ev):
                fired.append(self.log[-1])
            else:
                still.append(ev)
        self.pending = still
        return fired

    def _victim(self, ev: ChaosEvent) -> Optional[Instance]:
        running = sorted(self.fleet.running(), key=lambda i: i.id)
        if ev.kind == "revoke":
            # a second notice to an already-revoked instance is a no-op
            # in EC2 and would double-count here
            running = [i for i in running if i.revoke_at is None]
        if not running:
            return None
        return running[ev.victim % len(running)]

    def _apply(self, ev: ChaosEvent, target: Optional[Instance] = None) -> bool:
        """Try to fire ``ev``; False = no eligible target yet (stay pending)."""
        now = self.clock.now()
        if ev.kind == "truncate_blob":
            if self.store is None:
                return False
            keys = sorted(i.key for i in self.store.list("kvprefix/"))
            if not keys:
                return False
            key = keys[ev.victim % len(keys)]
            # bypass the monkey's own flaky_storage wrapper: corruption
            # must land deterministically, not bounce off its own fault
            get = self._storage_orig_get or self.store.get_bytes
            put = self._storage_orig_put or self.store.put_bytes
            put(key, get(key)[: len(get(key)) // 2])
            self.counters["blobs_truncated"] += 1
            self._record(ev.kind, key, now)
            return True
        if ev.kind == "flaky_storage":
            if self.store is None:
                return False
            self._arm_flaky_storage(ev, now)
            self._record(ev.kind, ev.scope or "*", now)
            return True
        if ev.kind == "flaky_queue":
            if self.queue is None:
                return False
            self._arm_flaky_queue(ev, now)
            self._record(ev.kind, self.queue.path, now)
            return True
        inst = target if target is not None else self._victim(ev)
        if inst is None:
            return False
        if ev.kind == "kill":
            self.fleet.terminate_instance(inst.id, reason="chaos-kill")
            self.counters["kills"] += 1
        elif ev.kind == "revoke":
            if inst.revoke_at is not None:
                return False
            inst.revoke_at = now + float(ev.notice_seconds)
            self.counters["revocations"] += 1
        elif ev.kind == "delay_heartbeat":
            self._suppress[inst.id] = now + float(ev.duration)
            self.counters["heartbeat_delays"] += 1
        self._record(ev.kind, inst.id, now)
        return True

    # ------------------------------------------------------ flaky windows
    def _arm_flaky_storage(self, ev: ChaosEvent, now: float) -> None:
        """Open (or extend) the transient-storage-fault window.  The
        store's methods are wrapped once; the wrapper injects at most one
        ``ConnectionError`` per (op, key) per window, so any caller with
        a single retry always makes progress and no data is ever lost."""
        if self._storage_orig_put is None:
            self._storage_orig_put = self.store.put_bytes
            self._storage_orig_get = self.store.get_bytes

            def flaky(op, orig):
                def call(key, *a, **kw):
                    self._maybe_storage_fault(op, key)
                    return orig(key, *a, **kw)

                return call

            self.store.put_bytes = flaky("put", self._storage_orig_put)
            self.store.get_bytes = flaky("get", self._storage_orig_get)
        self._storage_until = max(self._storage_until, now + float(ev.duration))
        # comma-separated key prefixes; empty = every key is fair game
        self._storage_scope = tuple(p for p in ev.scope.split(",") if p)
        self._storage_failed.clear()  # fresh window: keys fault again

    def _maybe_storage_fault(self, op: str, key: str) -> None:
        if self.clock.now() >= self._storage_until:
            return
        if self._storage_scope and not any(
            key.startswith(p) for p in self._storage_scope
        ):
            return
        token = (op, key)
        if token in self._storage_failed:
            return
        self._storage_failed.add(token)
        self.counters["storage_faults"] += 1
        if self.logs is not None:
            self.logs.put("chaos", f"flaky_storage: transient {op} fault on {key}")
        raise ConnectionError(f"chaos flaky_storage: transient {op} of {key!r}")

    def _arm_flaky_queue(self, ev: ChaosEvent, now: float) -> None:
        if not self._queue_hooked:
            install_fault_hook(self.queue.path, self._queue_fault)
            self._queue_hooked = True
        self._queue_until = max(self._queue_until, now + float(ev.duration))
        self._queue_failed.clear()

    def _queue_fault(self, op: str, path: str) -> None:
        """Per-path hook called from every ``DurableQueue`` handle on the
        shared file: the first consumer call of each op kind inside the
        window fails transiently; the retry (and everyone after) succeeds."""
        if self.clock.now() >= self._queue_until:
            return
        if op in self._queue_failed:
            return
        self._queue_failed.add(op)
        self.counters["queue_faults"] += 1
        if self.logs is not None:
            self.logs.put("chaos", f"flaky_queue: transient {op} fault on {path}")
        raise ConnectionError(f"chaos flaky_queue: transient {op} on {path!r}")

    def _record(self, kind: str, target: str, now: float) -> None:
        self.log.append(ChaosRecord(kind=kind, target=target, time=now))
        if self.logs is not None:
            self.logs.put("chaos", f"{kind} -> {target} at t={now:.0f}")

"""ECS analogue: task definitions, services, and container placement.

The paper's behaviours reproduced here:

- a *task definition* encodes the container's resource envelope
  (CPU_SHARES, MEMORY) and run settings (CHECK_IF_DONE, DOCKER_CORES, ...);
- a *service* says how many copies you want (CLUSTER_MACHINES ×
  TASKS_PER_MACHINE);
- placement bin-packs tasks onto instances **by resources**: a task larger
  than the instance never places, and an instance bigger than intended
  will take more tasks than you meant ("ECS will keep placing Dockers onto
  an instance until it is full") — both are reproduced and unit-tested;
- when a container is placed it names its instance after the app
  (paper Step 3, automatic actions 1–2).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .config import DSConfig
from .fleet import Instance, InstanceState, SpotFleet


@dataclass
class TaskDefinition:
    family: str
    payload: str
    cpu_shares: int  # 1024 == 1 vCPU
    memory_mb: int
    docker_cores: int
    env: Dict[str, str] = field(default_factory=dict)
    check_if_done: bool = True
    expected_number_files: int = 1
    min_file_size_bytes: int = 1
    necessary_string: str = ""
    seconds_to_start: float = 0.0

    @classmethod
    def from_config(cls, cfg: DSConfig) -> "TaskDefinition":
        return cls(
            family=f"{cfg.app_name}Task",
            payload=cfg.payload,
            cpu_shares=cfg.cpu_shares,
            memory_mb=cfg.memory_mb,
            docker_cores=cfg.docker_cores,
            env=dict(cfg.env),
            check_if_done=cfg.check_if_done,
            expected_number_files=cfg.expected_number_files,
            min_file_size_bytes=cfg.min_file_size_bytes,
            necessary_string=cfg.necessary_string,
            seconds_to_start=cfg.seconds_to_start,
        )


@dataclass
class Task:
    id: str
    definition: TaskDefinition
    instance_id: str
    started_at: float


@dataclass
class Service:
    name: str
    task_definition: TaskDefinition
    desired_count: int


class ECSCluster:
    """Tracks services and places tasks onto fleet instances."""

    def __init__(self, name: str = "default"):
        self.name = name
        self.services: Dict[str, Service] = {}
        self.tasks: Dict[str, Task] = {}
        self._ids = itertools.count()

    # -- control-plane ops ---------------------------------------------------
    def register_service(self, service: Service) -> None:
        self.services[service.name] = service

    def update_desired_count(self, service_name: str, count: int) -> None:
        self.services[service_name].desired_count = int(count)

    def deregister_service(self, service_name: str) -> None:
        # match tasks by the service's task definition: the definition
        # family is "<app>Task" while the service is "<app>Service", so
        # the old family.startswith(service_name) check never fired and
        # task records leaked past teardown
        svc = self.services.pop(service_name, None)
        if svc is None:
            return
        for tid in [t for t, task in self.tasks.items()
                    if task.definition == svc.task_definition]:
            self.tasks.pop(tid, None)

    # -- placement -------------------------------------------------------------
    def _fits(self, td: TaskDefinition, inst: Instance) -> bool:
        used_cpu = sum(self.tasks[t].definition.cpu_shares for t in inst.tasks if t in self.tasks)
        used_mem = sum(self.tasks[t].definition.memory_mb for t in inst.tasks if t in self.tasks)
        cap_cpu = inst.machine_type.vcpus * 1024
        cap_mem = inst.machine_type.memory_mb
        return used_cpu + td.cpu_shares <= cap_cpu and used_mem + td.memory_mb <= cap_mem

    def place(self, service_name: str, fleet: SpotFleet, now: float) -> List[Task]:
        """Place tasks for ``service`` until desired_count is met or no
        instance has room.  Returns newly placed tasks."""
        svc = self.services[service_name]
        live = {t: task for t, task in self.tasks.items()}
        current = [
            t
            for t, task in live.items()
            # equality, not identity: a re-registered service (same config,
            # new TaskDefinition object) must still count its live tasks
            # or placement doubles up
            if task.definition == svc.task_definition
            and fleet.instances.get(task.instance_id) is not None
            and fleet.instances[task.instance_id].state == InstanceState.RUNNING
        ]
        placed: List[Task] = []
        deficit = svc.desired_count - len(current)
        if deficit <= 0:
            return placed
        for inst in fleet.running():
            while deficit > 0 and self._fits(svc.task_definition, inst):
                tid = f"task-{next(self._ids):06d}"
                task = Task(id=tid, definition=svc.task_definition, instance_id=inst.id, started_at=now)
                self.tasks[tid] = task
                inst.tasks.append(tid)
                if not inst.name:
                    # "When a Docker container gets placed it gives the
                    # instance it's on its own name."
                    inst.name = f"{svc.name}-{inst.id}"
                placed.append(task)
                deficit -= 1
            if deficit <= 0:
                break
        return placed

    def reap_dead_tasks(self, fleet: SpotFleet) -> List[Task]:
        """Drop tasks whose instance has terminated; their in-flight jobs
        resurface via the queue's visibility timeout."""
        dead = []
        for tid, task in list(self.tasks.items()):
            inst = fleet.instances.get(task.instance_id)
            if inst is None or inst.state == InstanceState.TERMINATED:
                dead.append(self.tasks.pop(tid))
        return dead

"""Log groups/streams — the CloudWatch analogue.

Per the paper: each job writes a log of tool output; each container
writes a per-instance log of CPU/memory/disk usage; at teardown the
monitor exports all logs to the object store (S3 export task).
"""

from __future__ import annotations

import json
import threading
from collections import defaultdict
from typing import Dict, List, Optional

from .clock import Clock, WallClock
from .storage import ObjectStore


class LogGroup:
    def __init__(self, name: str, clock: Optional[Clock] = None):
        self.name = name
        self.clock = clock or WallClock()
        self._streams: Dict[str, List[dict]] = defaultdict(list)
        self._lock = threading.Lock()

    def put(self, stream: str, message: str, **fields) -> None:
        event = {"ts": self.clock.now(), "message": message}
        if fields:
            event.update(fields)
        with self._lock:
            self._streams[stream].append(event)

    def streams(self) -> List[str]:
        with self._lock:
            return sorted(self._streams)

    def events(self, stream: str) -> List[dict]:
        with self._lock:
            return list(self._streams.get(stream, []))

    def export(self, store: ObjectStore, prefix: str) -> int:
        """Export all streams as JSONL objects (the S3 export task)."""
        n = 0
        with self._lock:
            items = {s: list(evs) for s, evs in self._streams.items()}
        for stream, events in items.items():
            body = "\n".join(json.dumps(e, sort_keys=True) for e in events)
            store.put_text(f"{prefix}/{self.name}/{stream}.jsonl", body)
            n += 1
        return n


class MetricRegistry:
    """Minimal CloudWatch-metrics analogue: last-value gauges + counters,
    queried by the monitor for alarm evaluation."""

    def __init__(self, clock: Optional[Clock] = None):
        self.clock = clock or WallClock()
        self._gauges: Dict[str, tuple] = {}
        self._lock = threading.Lock()

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = (self.clock.now(), float(value))

    def read(self, name: str) -> Optional[tuple]:
        with self._lock:
            return self._gauges.get(name)

    def snapshot(self) -> Dict[str, tuple]:
        with self._lock:
            return dict(self._gauges)

"""Spot fleet — elastic, preemptible capacity (the paper's EC2 spot fleet).

The fleet request has a *target capacity* and a *bid*; the market decides
what you actually get and may take instances back at any time.  The paper
leans on three behaviours we reproduce faithfully:

1. capacity arrives asynchronously ("a couple of minutes to several
   hours"), so submission and execution are decoupled via the queue;
2. any instance can be preempted mid-job ("spot prices rising above your
   maximum bid, machine crashes, etc."); recovery is the queue's
   visibility timeout, not fleet-level state;
3. the monitor replaces crashed/idle instances unless "cheapest" mode.

The market is deterministic given a seed, so node-failure tests are
reproducible.  Preemption draws use an exponential inter-arrival model
per instance (rate = ``preemption_rate_per_hour``).
"""

from __future__ import annotations

import itertools
import random
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional

from .clock import Clock, WallClock
from .config import MACHINE_CATALOGUE, FleetFile, MachineType


class InstanceState(str, Enum):
    PENDING = "pending"
    RUNNING = "running"
    TERMINATED = "terminated"


@dataclass
class Instance:
    id: str
    machine_type: MachineType
    state: InstanceState
    launch_time: float
    ready_time: float  # when it transitions PENDING -> RUNNING
    terminate_time: Optional[float] = None
    terminate_reason: str = ""
    # ECS bookkeeping: task ids placed on this instance
    tasks: List[str] = field(default_factory=list)
    # liveness: last heartbeat from any task on this instance
    last_heartbeat: float = 0.0
    name: str = ""  # the Docker names its instance when placed (paper step 3.2)
    # spot-revocation notice: when set, this instance will be terminated
    # at this time (the EC2 two-minute warning).  Workers observe it via
    # WorkerContext.revoked() and drain gracefully before the deadline.
    revoke_at: Optional[float] = None


class SpotMarket:
    """Deterministic spot-market simulation."""

    def __init__(self, fleet_file: FleetFile, clock: Clock):
        self.ff = fleet_file
        self.clock = clock
        self.rng = random.Random(fleet_file.market_seed)
        self.capacity = fleet_file.capacity

    def current_price(self, mt: MachineType) -> float:
        base = mt.on_demand_price * 0.35  # typical spot discount
        if self.ff.price_volatility > 0:
            base *= 1.0 + self.rng.uniform(-1, 1) * self.ff.price_volatility
        return max(base, 0.001)

    def draw_lifetime(self) -> float:
        """Seconds until this instance is preempted (inf if rate==0)."""
        rate = self.ff.preemption_rate_per_hour
        if rate <= 0:
            return float("inf")
        return self.rng.expovariate(rate / 3600.0)


class SpotFleet:
    """A fleet request: maintains ``target_capacity`` instances via the market."""

    def __init__(
        self,
        fleet_file: FleetFile,
        *,
        clock: Optional[Clock] = None,
        app_name: str = "DS",
    ):
        self.clock = clock or WallClock()
        self.ff = fleet_file
        self.app_name = app_name
        self.market = SpotMarket(fleet_file, self.clock)
        self.instances: Dict[str, Instance] = {}
        self.target_capacity = 0
        self.bid: float = 0.0
        self.machine_types: List[MachineType] = []
        self.active = False
        self.replace_on_terminate = True  # disabled by cheapest mode
        self._ids = itertools.count()
        self._preempt_at: Dict[str, float] = {}
        self.request_id: str = ""

    # -- request lifecycle -------------------------------------------------
    def request(self, *, target_capacity: int, bid: float, machine_types: List[str]) -> str:
        self.target_capacity = int(target_capacity)
        self.bid = float(bid)
        self.machine_types = [MACHINE_CATALOGUE[m] for m in machine_types]
        self.active = True
        self.request_id = f"sfr-{self.app_name.lower()}-{next(self._ids):04d}"
        self.tick()
        return self.request_id

    def modify_target(self, target_capacity: int) -> None:
        self.target_capacity = int(target_capacity)

    def cancel(self, *, terminate_instances: bool = True) -> None:
        self.active = False
        self.target_capacity = 0
        if terminate_instances:
            for inst in self.running() + self.pending():
                self._terminate(inst, "fleet-cancelled")

    # -- views ---------------------------------------------------------------
    def running(self) -> List[Instance]:
        return [i for i in self.instances.values() if i.state == InstanceState.RUNNING]

    def pending(self) -> List[Instance]:
        return [i for i in self.instances.values() if i.state == InstanceState.PENDING]

    def alive(self) -> List[Instance]:
        return self.running() + self.pending()

    def fulfilled_capacity(self) -> int:
        return len(self.alive())

    # -- simulation step -------------------------------------------------------
    def tick(self) -> List[Instance]:
        """Advance market state; returns instances terminated this tick."""
        now = self.clock.now()
        terminated: List[Instance] = []

        # 1. preemptions & price-outs
        for inst in list(self.instances.values()):
            if inst.state == InstanceState.TERMINATED:
                continue
            # revocation-notice deadline (chaos-injected or market): the
            # warning window has elapsed, the instance is taken back
            if inst.revoke_at is not None and now >= inst.revoke_at:
                self._terminate(inst, "spot-revocation")
                terminated.append(inst)
                continue
            if self._preempt_at.get(inst.id, float("inf")) <= now:
                self._terminate(inst, "spot-preemption")
                terminated.append(inst)
                continue
            price = self.market.current_price(inst.machine_type)
            if price > self.bid:
                self._terminate(inst, "price-above-bid")
                terminated.append(inst)

        # 2. pending -> running
        for inst in self.pending():
            if now >= inst.ready_time:
                inst.state = InstanceState.RUNNING
                inst.last_heartbeat = now

        # 3. launch up to target (only while the request is active and
        #    replacement allowed — cheapest mode stops back-fill)
        if self.active:
            deficit = self.target_capacity - self.fulfilled_capacity()
            if deficit > 0 and not self.replace_on_terminate and self.fulfilled_capacity() > 0:
                deficit = 0
            for _ in range(max(0, deficit)):
                if len(self.alive()) >= self.market.capacity:
                    break
                mt = self._cheapest_affordable()
                if mt is None:
                    break  # out-bid: capacity stays unfulfilled (paper: "several hours")
                iid = f"i-{self.app_name.lower()}{next(self._ids):06d}"
                inst = Instance(
                    id=iid,
                    machine_type=mt,
                    state=InstanceState.PENDING,
                    launch_time=now,
                    ready_time=now + self.ff.startup_seconds,
                    last_heartbeat=now,
                )
                self.instances[iid] = inst
                life = self.market.draw_lifetime()
                self._preempt_at[iid] = now + life if life != float("inf") else float("inf")

        # 4. excess capacity above target is released (AWS terminates on
        #    downscale with lowest-price strategy)
        excess = self.fulfilled_capacity() - self.target_capacity
        if excess > 0:
            # prefer terminating pending, then idle (no tasks) instances
            victims = sorted(
                self.alive(),
                key=lambda i: (i.state == InstanceState.RUNNING, len(i.tasks)),
            )[:excess]
            for inst in victims:
                self._terminate(inst, "downscale")
                terminated.append(inst)
        return terminated

    def terminate_instance(self, instance_id: str, reason: str = "manual") -> None:
        inst = self.instances.get(instance_id)
        if inst and inst.state != InstanceState.TERMINATED:
            self._terminate(inst, reason)

    # -- internals ----------------------------------------------------------
    def _cheapest_affordable(self) -> Optional[MachineType]:
        affordable = [
            mt for mt in self.machine_types if self.market.current_price(mt) <= self.bid
        ]
        if not affordable:
            return None
        return min(affordable, key=self.market.current_price)

    def _terminate(self, inst: Instance, reason: str) -> None:
        inst.state = InstanceState.TERMINATED
        inst.terminate_time = self.clock.now()
        inst.terminate_reason = reason
        inst.tasks.clear()
